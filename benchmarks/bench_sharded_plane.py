"""Sharded-fleet-plane benchmark: end-to-end ``run_afl`` events/s with the
(M, n) fleet buffer sharded over a simulated 8-device ``fleet`` mesh
(docs/DESIGN.md §6) vs the single-device PR-2 plane, at M=64.

The device count locks at jax init, so this bench RE-EXECS itself into a
child process with ``--xla_force_host_platform_device_count=8`` before
importing jax — ``benchmarks/run.py`` (and the regression gate) can then
include it in any invocation regardless of the parent's device topology.

What the gate watches on this host: the sharded plane must stay within
the recorded ratio of the single-device plane AND match it to ≤1e-5.
On a 2-core CPU container with 8 *simulated* devices there is no real
parallel hardware — all shards time-share the same cores and the
shard_map adds partitioning overhead, so the honest same-run ratio here
is ~1x and the floor guards the "sharding started gathering the fleet /
recompiling per event" failure mode, not a speedup.  On a real multi-
chip mesh the same program trains M/D rows per chip concurrently —
re-record the baseline (and raise the floor) there.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

DEVICES = 8
M = 64
K = 2                      # local iterations per upload
LOCAL_BATCHES = 4          # minibatches per local iteration
BATCH_SIZE = 1
ITERATIONS = 64            # upload events per timed run
_CHILD_ENV = "REPRO_SHARDED_BENCH_CHILD"


def _bench_child() -> None:
    import jax
    import numpy as np

    from benchmarks.common import bench_seed, emit, save_result
    from repro.configs.paper_cnn import CNNConfig
    from repro.core.afl import run_afl
    from repro.core.scheduler import make_fleet
    from repro.core.tasks import CNNTask

    seed = bench_seed()
    cnn_cfg = CNNConfig(conv1=2, conv2=4, fc=16)   # CPU-budget width
    task = CNNTask(iid=True, num_clients=M, train_n=2048, test_n=128,
                   batch_size=BATCH_SIZE,
                   local_batches_per_step=LOCAL_BATCHES,
                   cnn_cfg=cnn_cfg, seed=seed)
    fleet = make_fleet(M, tau=1.0, hetero_a=4.0,
                       samples_per_client=task.num_samples(),
                       adaptive=False, base_local_steps=K, seed=seed)
    p0 = task.init_params()
    planes = {"single": task.client_plane(fleet),
              "sharded": task.client_plane(fleet, sharded=True)}

    def timed(plane):
        def run():
            return run_afl(p0, fleet, None, algorithm="csmaafl",
                           iterations=ITERATIONS, tau_u=0.1, tau_d=0.1,
                           gamma=0.4, client_plane=plane, seed=seed)
        r = run()                                   # warmup + compile
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        t0 = time.perf_counter()
        r = run()
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        return time.perf_counter() - t0, r

    t_single, r_single = timed(planes["single"])
    t_sharded, r_sharded = timed(planes["sharded"])
    speedup = t_single / t_sharded
    parity = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                     - np.asarray(b, np.float32))))
                 for a, b in zip(jax.tree.leaves(r_sharded.params),
                                 jax.tree.leaves(r_single.params)))
    emit("sharded_plane.run_afl.single_device",
         t_single * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_single:.1f} events/s")
    emit("sharded_plane.run_afl.fleet_mesh",
         t_sharded * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_sharded:.1f} events/s on "
         f"{len(jax.devices())} simulated devices; {speedup:.2f}x vs "
         f"single-device; parity {parity:.2e}")
    save_result("sharded_plane", {
        "model": "paper_cnn_cpu_budget", "M": M, "K": K,
        "local_batches": LOCAL_BATCHES, "batch_size": BATCH_SIZE,
        "iterations": ITERATIONS, "devices": len(jax.devices()),
        "seed": seed,
        "mode": planes["sharded"].engine.mode,
        "single_s": t_single, "sharded_s": t_sharded,
        "events_per_s_single": ITERATIONS / t_single,
        "events_per_s_sharded": ITERATIONS / t_sharded,
        "speedup": speedup, "parity_max_abs_diff": parity,
    })


def main() -> None:
    if os.environ.get(_CHILD_ENV):
        _bench_child()
        return
    env = dict(os.environ)
    env[_CHILD_ENV] = "1"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={DEVICES}").strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded_plane"],
        env=env, cwd=os.path.join(os.path.dirname(__file__), ".."))
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded-plane bench child exited {proc.returncode}")


if __name__ == "__main__":
    main()
