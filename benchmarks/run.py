"""Benchmark entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig2          §II-C / Fig.2 completion-time comparison (SFL vs AFL)
  convergence   Figs.3-5 FedAvg vs CSMAAFL, γ sweep (scaled by default;
                ``--full`` for the paper's 100-client/60k-image setup)
  kernels       Pallas-kernel oracles micro-bench
  aggregation   β-solver scaling + §III-A decay table + fused engine vs
                naive per-leaf blend (docs/DESIGN.md §3)
  roofline      §Roofline table from the dry-run records

``--gate`` runs ``benchmarks/check_regression.py`` afterwards and fails
the invocation on a >1.3x aggregation slowdown vs the committed baseline
(``make bench-gate`` = ``--only aggregation --gate``; ``make bench-agg``
runs ungated).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,convergence,kernels,"
                         "aggregation,roofline")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="fail on aggregation-bench regression vs the "
                         "committed baseline")
    args = ap.parse_args(argv)
    names = (args.only.split(",") if args.only else
             ["fig2", "aggregation", "kernels", "convergence", "roofline"])
    print("name,us_per_call,derived")
    rc = 0
    agg_ran = False
    for name in names:
        try:
            if name == "fig2":
                from benchmarks import bench_fig2_timing as b
                b.main()
            elif name == "convergence":
                from benchmarks import bench_convergence as b
                b.main(["--full"] if args.full else [])
            elif name == "kernels":
                from benchmarks import bench_kernels as b
                b.main()
            elif name == "aggregation":
                from benchmarks import bench_aggregation as b
                b.main()
                agg_ran = True
            elif name == "roofline":
                from benchmarks import bench_roofline as b
                b.main()
            else:
                print(f"{name},0,unknown-benchmark", file=sys.stderr)
        except Exception:  # noqa: BLE001
            rc = 1
            print(f"{name},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.gate:
        # only gate on a result THIS invocation produced — a stale
        # aggregation_fused.json from an earlier run proves nothing
        if not agg_ran:
            print("gate: aggregation bench did not run (or failed) in "
                  "this invocation — nothing to gate", file=sys.stderr)
            rc = max(rc, 2)
        else:
            from benchmarks import check_regression
            rc = max(rc, check_regression.check())
    return rc


if __name__ == "__main__":
    sys.exit(main())
