"""Benchmark entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig2           §II-C / Fig.2 completion-time comparison (SFL vs AFL)
  convergence    Figs.3-5 FedAvg vs CSMAAFL, γ sweep (scaled by default;
                 ``--full`` for the paper's 100-client/60k-image setup)
  kernels        Pallas-kernel oracles micro-bench
  aggregation    β-solver scaling + §III-A decay table + fused engine vs
                 naive per-leaf blend (docs/DESIGN.md §3)
  client_plane   fused fleet plane vs per-minibatch run_afl on the paper
                 CNN at M=32 (docs/DESIGN.md §4)
  sharded_plane  fleet-mesh-sharded plane vs single-device plane at M=64
                 on 8 simulated devices (docs/DESIGN.md §6; re-execs
                 itself into a child process to set the device count)
  compiled_loop  whole-run event-trace compiler vs the per-window fleet
                 plane loop at M=64 (docs/DESIGN.md §7)
  sweep_plane    run-batched seeds x scenarios grid vs sequential
                 compiled runs (docs/DESIGN.md §8)
  faults         fault-injection staging cost vs the clean trace +
                 realization determinism (docs/DESIGN.md §9)
  guards         in-scan update-guard + crash-safe autosave overhead on
                 the compiled run (docs/DESIGN.md §10)
  ingest         streaming-ingest micro-batching vs per-event serving +
                 live-vs-replay parity + open-loop latency
                 (docs/DESIGN.md §11)
  fleet_store    paged active-set pool overhead vs the dense plane at
                 small M + arena->device staging throughput
                 (docs/DESIGN.md §12)
  roofline       §Roofline table from the dry-run records

Results land in the GITIGNORED ``experiments/bench/local/``; pass
``--record`` to also refresh the tracked ``experiments/bench/*.json``
host record (so casual local runs never dirty the tree).

``--gate`` runs ``benchmarks/check_regression.py`` afterwards for every
gated benchmark THIS invocation produced and fails on a >1.3x slowdown
vs the committed baselines (``make bench-gate`` runs all nine gated
benches; ``make bench-agg`` / ``make bench-client`` / ``make
bench-sharded`` / ``make bench-compiled`` / ``make bench-sweep`` /
``make bench-faults`` / ``make bench-guards`` / ``make bench-ingest`` /
``make bench-fleet`` run ungated).  Gate results also land in ``experiments/bench/local/
gate_report.json`` (machine-readable, one record per gate).

CI-friendliness: ``--seed N`` pins every bench's fleet/batch draws
(exported as ``REPRO_BENCH_SEED`` so subprocess benches see it too) and
``--json PATH`` writes one combined JSON with every bench result this
invocation produced plus the exit code — reproducible run-to-run, no
interactive stdout parsing needed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

GATED = ("aggregation", "client_plane", "sharded_plane", "compiled_loop",
         "sweep_plane", "faults", "guards", "ingest", "fleet_store")
# bench name -> result file written via benchmarks.common.save_result
RESULT_FILES = {
    "aggregation": "aggregation_fused.json",
    "client_plane": "client_plane.json",
    "sharded_plane": "sharded_plane.json",
    "compiled_loop": "compiled_loop.json",
    "sweep_plane": "sweep_plane.json",
    "faults": "faults.json",
    "guards": "guards.json",
    "ingest": "ingest.json",
    "fleet_store": "fleet_store.json",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,convergence,kernels,"
                         "aggregation,client_plane,sharded_plane,"
                         "compiled_loop,sweep_plane,faults,guards,"
                         "ingest,fleet_store,roofline")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="fail on bench regression vs the committed "
                         "baselines")
    ap.add_argument("--seed", type=int, default=None,
                    help="pin the bench seed (REPRO_BENCH_SEED) for "
                         "reproducible CI runs")
    ap.add_argument("--json", default=None, dest="json_path",
                    help="write every produced bench result + exit code "
                         "to this JSON file")
    ap.add_argument("--record", action="store_true",
                    help="also refresh the TRACKED experiments/bench/"
                         "*.json records (default: results go only to "
                         "the gitignored experiments/bench/local/)")
    args = ap.parse_args(argv)
    if args.seed is not None:
        # env, not a function argument: subprocess benches (sharded_plane)
        # and lazily-imported bench modules all read the same knob
        os.environ["REPRO_BENCH_SEED"] = str(args.seed)
    if args.record:
        os.environ["REPRO_BENCH_RECORD"] = "1"
    names = (args.only.split(",") if args.only else
             ["fig2", "aggregation", "client_plane", "sharded_plane",
              "compiled_loop", "sweep_plane", "faults", "guards",
              "ingest", "fleet_store", "kernels", "convergence",
              "roofline"])
    print("name,us_per_call,derived")
    rc = 0
    ran = set()
    failed = []
    for name in names:
        try:
            if name == "sweep_plane":
                from benchmarks import bench_sweep_plane as b
                b.main()
            elif name == "fig2":
                from benchmarks import bench_fig2_timing as b
                b.main()
            elif name == "convergence":
                from benchmarks import bench_convergence as b
                b.main(["--full"] if args.full else [])
            elif name == "kernels":
                from benchmarks import bench_kernels as b
                b.main()
            elif name == "aggregation":
                from benchmarks import bench_aggregation as b
                b.main()
            elif name == "client_plane":
                from benchmarks import bench_client_plane as b
                b.main()
            elif name == "sharded_plane":
                from benchmarks import bench_sharded_plane as b
                b.main()
            elif name == "compiled_loop":
                from benchmarks import bench_compiled_loop as b
                b.main()
            elif name == "faults":
                from benchmarks import bench_faults as b
                b.main()
            elif name == "guards":
                from benchmarks import bench_guards as b
                b.main()
            elif name == "ingest":
                from benchmarks import bench_ingest as b
                b.main()
            elif name == "fleet_store":
                from benchmarks import bench_fleet_store as b
                b.main()
            elif name == "roofline":
                from benchmarks import bench_roofline as b
                b.main()
            else:
                print(f"{name},0,unknown-benchmark", file=sys.stderr)
                continue
            ran.add(name)
        except Exception:  # noqa: BLE001
            rc = 1
            failed.append(name)
            print(f"{name},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    gate_records = []
    if args.gate:
        # only gate on results THIS invocation produced — a stale JSON
        # from an earlier run proves nothing; a REQUESTED gated bench
        # that crashed must fail the gate, not silently escape it
        gated_requested = {n for n in names if n in GATED}
        gated_ran = gated_requested & ran
        missing = gated_requested - gated_ran
        if missing:
            print(f"gate: gated benchmark(s) {sorted(missing)} did not "
                  "run (or failed) in this invocation", file=sys.stderr)
            rc = max(rc, 2)
        if not gated_ran:
            print("gate: no gated benchmark ran (or all failed) in this "
                  "invocation — nothing to gate", file=sys.stderr)
            rc = max(rc, 2)
        else:
            from benchmarks import check_regression
            codes = []
            for g in sorted(gated_ran):
                code, rec = check_regression.check_gate(
                    g, enforce=check_regression.enforcing())
                codes.append(code)
                gate_records.append(rec)
            gate_rc = check_regression.combine_codes(codes)
            check_regression.write_report(
                check_regression.DEFAULT_REPORT, gate_records, gate_rc,
                check_regression.THRESHOLD)
            rc = max(rc, gate_rc)
    if args.json_path:
        results = {}
        from benchmarks.common import RESULTS_DIR
        for name in ran:
            fn = RESULT_FILES.get(name)
            if fn is None:
                continue
            path = os.path.join(RESULTS_DIR, fn)
            if os.path.exists(path):
                with open(path) as f:
                    results[name] = json.load(f)
        payload = {"seed": args.seed, "ran": sorted(ran),
                   "failed": failed, "exit_code": rc,
                   "results": results,
                   "gates": {r["gate"]: r for r in gate_records}}
        with open(args.json_path, "w") as f:
            json.dump(payload, f, indent=1, default=float)
        print(f"bench: results written to {args.json_path}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
