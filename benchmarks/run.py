"""Benchmark entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig2          §II-C / Fig.2 completion-time comparison (SFL vs AFL)
  convergence   Figs.3-5 FedAvg vs CSMAAFL, γ sweep (scaled by default;
                ``--full`` for the paper's 100-client/60k-image setup)
  kernels       Pallas-kernel oracles micro-bench
  aggregation   β-solver scaling + §III-A decay table + fused engine vs
                naive per-leaf blend (docs/DESIGN.md §3)
  client_plane  fused fleet plane vs per-minibatch run_afl on the paper
                CNN at M=32 (docs/DESIGN.md §4)
  roofline      §Roofline table from the dry-run records

``--gate`` runs ``benchmarks/check_regression.py`` afterwards for every
gated benchmark THIS invocation produced and fails on a >1.3x slowdown
vs the committed baselines (``make bench-gate`` =
``--only aggregation,client_plane --gate``; ``make bench-agg`` /
``make bench-client`` run ungated).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,convergence,kernels,"
                         "aggregation,client_plane,roofline")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--gate", action="store_true",
                    help="fail on bench regression vs the committed "
                         "baselines")
    args = ap.parse_args(argv)
    names = (args.only.split(",") if args.only else
             ["fig2", "aggregation", "client_plane", "kernels",
              "convergence", "roofline"])
    print("name,us_per_call,derived")
    rc = 0
    gated_ran = set()
    for name in names:
        try:
            if name == "fig2":
                from benchmarks import bench_fig2_timing as b
                b.main()
            elif name == "convergence":
                from benchmarks import bench_convergence as b
                b.main(["--full"] if args.full else [])
            elif name == "kernels":
                from benchmarks import bench_kernels as b
                b.main()
            elif name == "aggregation":
                from benchmarks import bench_aggregation as b
                b.main()
                gated_ran.add("aggregation")
            elif name == "client_plane":
                from benchmarks import bench_client_plane as b
                b.main()
                gated_ran.add("client_plane")
            elif name == "roofline":
                from benchmarks import bench_roofline as b
                b.main()
            else:
                print(f"{name},0,unknown-benchmark", file=sys.stderr)
        except Exception:  # noqa: BLE001
            rc = 1
            print(f"{name},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.gate:
        # only gate on results THIS invocation produced — a stale JSON
        # from an earlier run proves nothing; a REQUESTED gated bench
        # that crashed must fail the gate, not silently escape it
        gated_requested = {n for n in names
                           if n in ("aggregation", "client_plane")}
        missing = gated_requested - gated_ran
        if missing:
            print(f"gate: gated benchmark(s) {sorted(missing)} did not "
                  "run (or failed) in this invocation", file=sys.stderr)
            rc = max(rc, 2)
        if not gated_ran:
            print("gate: no gated benchmark ran (or all failed) in this "
                  "invocation — nothing to gate", file=sys.stderr)
            rc = max(rc, 2)
        else:
            from benchmarks import check_regression
            for g in sorted(gated_ran):
                rc = max(rc, check_regression.check_gate(g))
    return rc


if __name__ == "__main__":
    sys.exit(main())
