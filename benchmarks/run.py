"""Benchmark entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig2          §II-C / Fig.2 completion-time comparison (SFL vs AFL)
  convergence   Figs.3-5 FedAvg vs CSMAAFL, γ sweep (scaled by default;
                ``--full`` for the paper's 100-client/60k-image setup)
  kernels       Pallas-kernel oracles micro-bench
  aggregation   β-solver scaling + §III-A decay table
  roofline      §Roofline table from the dry-run records
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig2,convergence,kernels,"
                         "aggregation,roofline")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    names = (args.only.split(",") if args.only else
             ["fig2", "aggregation", "kernels", "convergence", "roofline"])
    print("name,us_per_call,derived")
    rc = 0
    for name in names:
        try:
            if name == "fig2":
                from benchmarks import bench_fig2_timing as b
                b.main()
            elif name == "convergence":
                from benchmarks import bench_convergence as b
                b.main(["--full"] if args.full else [])
            elif name == "kernels":
                from benchmarks import bench_kernels as b
                b.main()
            elif name == "aggregation":
                from benchmarks import bench_aggregation as b
                b.main()
            elif name == "roofline":
                from benchmarks import bench_roofline as b
                b.main()
            else:
                print(f"{name},0,unknown-benchmark", file=sys.stderr)
        except Exception:  # noqa: BLE001
            rc = 1
            print(f"{name},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    return rc


if __name__ == "__main__":
    sys.exit(main())
