"""Aggregation-path benchmarks: β-solver scaling (eqs. 9-10) and the
server blend op at model scale (eq. 3/11 folded), plus the §III-A
effective-coefficient decay table."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_result, time_fn
from repro.core import aggregation as agg


def bench_beta_solver() -> None:
    rng = np.random.default_rng(0)
    rows = {}
    for M in (10, 100, 1000, 10000):
        alpha = rng.dirichlet(np.ones(M))
        sched = list(rng.permutation(M))
        us = time_fn(lambda: agg.solve_betas(alpha, sched), warmup=1,
                     iters=5)
        rows[M] = us
        emit(f"agg.solve_betas.M{M}", us, "closed-form backward recursion")
    save_result("beta_solver_scaling", rows)


def bench_decay_table() -> None:
    """§III-A: iterations until the first upload's weight halves/vanishes,
    for uniform alpha over M clients."""
    rows = {}
    for M in (10, 100):
        a = 1.0 / M
        # weight of first upload after J iterations: a*(1-a)^(J-1)
        j_half = int(np.ceil(1 + np.log(0.5) / np.log(1 - a)))
        j_1pct = int(np.ceil(1 + np.log(0.01) / np.log(1 - a)))
        rows[M] = {"half": j_half, "1pct": j_1pct}
        emit(f"agg.decay.M{M}.iters_to_1pct", j_1pct,
             "naive alpha-in-AFL (claim C2)")
    save_result("alpha_decay", rows)


def main() -> None:
    bench_beta_solver()
    bench_decay_table()


if __name__ == "__main__":
    main()
