"""Aggregation-path benchmarks: β-solver scaling (eqs. 9-10), the §III-A
effective-coefficient decay table, and the fused flat-buffer engine vs
the naive per-leaf server blend (docs/DESIGN.md §3) on the paper's CNN."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_result, time_fn
from repro.core import aggregation as agg


def bench_beta_solver() -> None:
    rng = np.random.default_rng(0)
    rows = {}
    for M in (10, 100, 1000, 10000):
        alpha = rng.dirichlet(np.ones(M))
        sched = list(rng.permutation(M))
        us = time_fn(lambda: agg.solve_betas(alpha, sched), warmup=1,
                     iters=5)
        rows[M] = us
        emit(f"agg.solve_betas.M{M}", us, "closed-form backward recursion")
    save_result("beta_solver_scaling", rows)


def bench_decay_table() -> None:
    """§III-A: iterations until the first upload's weight halves/vanishes,
    for uniform alpha over M clients."""
    rows = {}
    for M in (10, 100):
        a = 1.0 / M
        # weight of first upload after J iterations: a*(1-a)^(J-1)
        j_half = int(np.ceil(1 + np.log(0.5) / np.log(1 - a)))
        j_1pct = int(np.ceil(1 + np.log(0.01) / np.log(1 - a)))
        rows[M] = {"half": j_half, "1pct": j_1pct}
        emit(f"agg.decay.M{M}.iters_to_1pct", j_1pct,
             "naive alpha-in-AFL (claim C2)")
    save_result("alpha_decay", rows)


def bench_fused_engine(trunk_k: int = 8) -> None:
    """Fused flat-buffer engine vs the naive per-leaf blend path on the
    paper's CNN (Section IV model, ~1.66M params).

    naive  — what the runtimes did pre-engine: K sequential
             ``blend_pytree`` tree.maps, O(leaves) dispatches per event.
    fused  — ONE ``agg_engine`` trunk launch: fold the K betas, stream
             the flat buffer through the Pallas kernel once (interpret
             mode off-TPU, so CPU numbers are conservative).
    """
    import jax

    from repro.configs.paper_cnn import MNIST_CNN
    from repro.core.agg_engine import AggEngine
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init_params(MNIST_CNN, key)
    n = sum(x.size for x in jax.tree.leaves(params))
    clients = [jax.tree.map(
        lambda x, i=i: x + 0.01 * (i + 1), params) for i in range(trunk_k)]
    betas = [0.5 + 0.45 * i / trunk_k for i in range(trunk_k)]

    def naive():
        w = params
        for c, b in zip(clients, betas):
            w = agg.blend_pytree(w, c, b)
        return w

    # donate=False: the bench re-blends from the same buffer every iter
    eng = AggEngine(params, donate=False)
    g_flat = eng.flatten(params)

    def fused():
        flat, _ = eng.blend_trunk_flat(g_flat, clients, betas)
        return flat

    def fused_single():
        flat, _ = eng.blend_flat(g_flat, clients[0], betas[0])
        return flat

    us_naive = time_fn(naive, warmup=2, iters=10)
    us_fused = time_fn(fused, warmup=2, iters=10)
    us_single = time_fn(fused_single, warmup=2, iters=10)
    speedup = us_naive / us_fused
    ev_naive = trunk_k / (us_naive * 1e-6)
    ev_fused = trunk_k / (us_fused * 1e-6)
    emit(f"agg.engine.naive_blend_K{trunk_k}", us_naive,
         f"per-leaf tree.map x{trunk_k}; {ev_naive:.0f} events/s")
    emit(f"agg.engine.fused_trunk_K{trunk_k}", us_fused,
         f"one fused launch ({eng.mode}); {ev_fused:.0f} events/s; "
         f"{speedup:.1f}x vs naive")
    emit("agg.engine.fused_single_event", us_single,
         f"C=1 fast path ({eng.mode})")
    payload = {
        "model": "paper_cnn", "params": int(n), "trunk_k": trunk_k,
        "mode": eng.mode,
        "naive_us": us_naive, "fused_us": us_fused,
        "fused_single_us": us_single, "speedup": speedup,
        "naive_events_per_s": ev_naive, "fused_events_per_s": ev_fused,
    }
    if eng.mode != "kernel":
        # informational: the real Pallas kernel through the interpreter
        # (tier-1 parity runs it; the interpreter's per-launch copies make
        # it uncompetitive for timing, hence the xla-mode default off-TPU)
        eng_k = AggEngine(params, donate=False, interpret=True)
        us_interp = time_fn(
            lambda: eng_k.blend_trunk_flat(g_flat, clients, betas)[0],
            warmup=1, iters=3)
        emit(f"agg.engine.kernel_interpret_trunk_K{trunk_k}", us_interp,
             "Pallas interpreter (informational)")
        payload["kernel_interpret_us"] = us_interp
    save_result("aggregation_fused", payload)


def main() -> None:
    bench_beta_solver()
    bench_decay_table()
    bench_fused_engine()


if __name__ == "__main__":
    main()
