"""Client-plane benchmark: end-to-end ``run_afl`` events/s with the fused
fleet plane (docs/DESIGN.md §4) vs the per-minibatch reference path, at
M=32 clients, K=4 local iterations.

plane-off — per-minibatch local SGD: O(K·local_batches) jit dispatches
            per upload event + a per-event client-pytree flatten at blend
            time.
plane-on  — scanned local SGD (batches staged as index arrays, gathered
            on device), event-window batched retrains (one vmapped
            launch per window of distinct uploaders), the blend
            dynamic-slicing the uploader's row out of the device-
            resident (M, n) fleet buffer.

The model is the paper-CNN *geometry* (2 conv + 2 maxpool + 2 FC on
28x28) at a CPU-budget width (same convention as bench_convergence's
scaled mode).  What the plane eliminates is per-step dispatch + per-op
launch overhead; the narrow width keeps the benchmark in the regime
where that overhead is visible at all on a small-CPU host.  NOTE the
measured speedup is strongly host-dependent: on this repo's 2-core CPU
container JAX dispatch is ~3us and conv compute dominates, capping the
end-to-end win near ~2x (at full paper width the two paths are
compute-equal by parity and the ratio approaches 1).  On dispatch-bound
hosts (accelerators, where a dispatch costs 50-200us and convs are
fast), the same mechanism is worth an order of magnitude — the ISSUE's
5x target assumes that regime.  The gate therefore pins the same-run
ratio against the committed baseline (the "someone re-introduced
per-minibatch dispatch" signal) with a floor at the measured-host level,
plus the plane-on/plane-off parity bound.

Also records plane-on/plane-off parity on the final params (gated
≤1e-5 by ``benchmarks/check_regression.py``).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, emit, save_result

M = 32
K = 4                      # local iterations per upload
LOCAL_BATCHES = 8          # minibatches per local iteration
BATCH_SIZE = 1
ITERATIONS = 64            # upload events per timed run


def _run(task, fleet, p0, plane, use_plane: bool):
    from repro.core.afl import run_afl
    return run_afl(p0, fleet, task.local_train_fn, algorithm="csmaafl",
                   iterations=ITERATIONS, tau_u=0.1, tau_d=0.1, gamma=0.4,
                   client_plane=plane, use_client_plane=use_plane)


def bench_client_plane() -> None:
    import jax

    from repro.configs.paper_cnn import CNNConfig
    from repro.core.scheduler import make_fleet
    from repro.core.tasks import CNNTask

    seed = bench_seed()
    cnn_cfg = CNNConfig(conv1=2, conv2=4, fc=16)   # CPU-budget width
    task = CNNTask(iid=True, num_clients=M, train_n=2048, test_n=128,
                   batch_size=BATCH_SIZE, local_batches_per_step=LOCAL_BATCHES,
                   cnn_cfg=cnn_cfg, seed=seed)
    fleet = make_fleet(M, tau=1.0, hetero_a=4.0,
                       samples_per_client=task.num_samples(),
                       adaptive=False, base_local_steps=K, seed=seed)
    p0 = task.init_params()
    plane = task.client_plane(fleet)

    def timed(use_plane):
        # warmup run compiles every bucket variant, then one timed run
        # (an end-to-end run IS the median of ITERATIONS events)
        r = _run(task, fleet, p0, plane, use_plane)
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        t0 = time.perf_counter()
        r = _run(task, fleet, p0, plane, use_plane)
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        return time.perf_counter() - t0, r

    t_off, r_off = timed(False)
    t_on, r_on = timed(True)
    ev_off = ITERATIONS / t_off
    ev_on = ITERATIONS / t_on
    speedup = t_off / t_on
    parity = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                     - np.asarray(b, np.float32))))
                 for a, b in zip(jax.tree.leaves(r_on.params),
                                 jax.tree.leaves(r_off.params)))
    emit("client_plane.run_afl.per_minibatch", t_off * 1e6 / ITERATIONS,
         f"{ev_off:.1f} events/s (K*B={K * LOCAL_BATCHES} dispatches/event)")
    emit("client_plane.run_afl.fused_plane", t_on * 1e6 / ITERATIONS,
         f"{ev_on:.1f} events/s; {speedup:.1f}x vs per-minibatch; "
         f"parity {parity:.2e}")
    save_result("client_plane", {
        "model": "paper_cnn_cpu_budget", "M": M, "K": K,
        "local_batches": LOCAL_BATCHES, "batch_size": BATCH_SIZE,
        "iterations": ITERATIONS, "seed": seed,
        "mode": plane.engine.mode,
        "off_s": t_off, "on_s": t_on,
        "events_per_s_off": ev_off, "events_per_s_on": ev_on,
        "speedup": speedup, "parity_max_abs_diff": parity,
    })


def main() -> None:
    bench_client_plane()


if __name__ == "__main__":
    main()
