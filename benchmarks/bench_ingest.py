"""Streaming-ingest plane benchmark (docs/DESIGN.md §11): what
micro-batching the upload stream buys over per-event serving, and
whether the live server still reproduces its offline replay.

Workload: the paper-CNN CPU-budget fleet (``bench_guards``'s geometry)
under a dense arrival burst on the VIRTUAL clock — arrival gaps far
below ``max_wait_ms``, so the batched server always closes full
``max_batch`` micro-batches while the unbatched comparison point
(``max_batch=1``, the ``lowlat`` preset) pays one launch per event.
Virtual time means no sleeps: both timings are pure service cost for
the same 256-event stream, so their ratio is the honest micro-batching
win on this host.

* ``speedup = unbatched_s / batched_s`` is the gated same-run ratio —
  a collapse (batch assembly falling back to per-event launches, a
  host sync per admission, per-batch recompiles) lands at ~1x.
* ``parity_max_abs_diff`` — the live batched run's recorded session
  replayed through ``compile_afl_trace(events=..., realized=True)`` as
  ONE compiled trace must match the live final model ≤1e-5 (gated).
  This is the serving-vs-simulator contract: micro-batch boundaries
  must be value-invisible.
* A short wall-clock open-loop Poisson run records p50/p99 event
  latency and sustained events/s as context (not gated — wall latency
  on a shared CI container is load-dependent).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, emit, save_result

M = 64
K = 1                      # local iterations per upload
LOCAL_BATCHES = 2          # minibatches per local iteration
BATCH_SIZE = 1
ITERATIONS = 256           # upload events per timed run
MAX_BATCH = 8              # ingest micro-batch depth
REPS = 3                   # median-of-REPS end-to-end runs per variant
RT_EVENTS = 96             # wall-clock context run
RT_RATE = 150.0            # offered load (events/s) for the context run


def bench_ingest() -> None:
    import jax

    from repro.api import RunConfig
    from repro.configs.paper_cnn import CNNConfig
    from repro.core import ingest as ing
    from repro.core.scheduler import make_fleet
    from repro.core.tasks import CNNTask

    seed = bench_seed()
    cnn_cfg = CNNConfig(conv1=2, conv2=4, fc=16)   # CPU-budget width
    task = CNNTask(iid=True, num_clients=M, train_n=2048, test_n=128,
                   batch_size=BATCH_SIZE,
                   local_batches_per_step=LOCAL_BATCHES,
                   cnn_cfg=cnn_cfg, seed=seed)
    fleet = make_fleet(M, tau=1.0, hetero_a=4.0,
                       samples_per_client=task.num_samples(),
                       adaptive=False, base_local_steps=K, seed=seed)
    p0 = task.init_params()
    plane = task.client_plane(fleet)
    # dense burst: 1ms gaps << max_wait, so batching saturates
    arrivals = ing.poisson_arrivals(1000.0, ITERATIONS, M=M, seed=seed)

    def cfg(max_batch):
        return RunConfig(
            algorithm="csmaafl", loop="ingest", iterations=ITERATIONS,
            seed=seed, ingest={"max_batch": max_batch,
                               "max_wait_ms": 10_000.0,
                               "queue_cap": max(4 * max_batch, 64)})

    def one(max_batch):
        return ing.run_ingest(task, cfg(max_batch), fleet=fleet,
                              client_plane=plane, params0=p0,
                              arrivals=arrivals)

    def timed(max_batch):
        r = one(max_batch)             # warmup compiles the variant
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        ts = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            r = one(max_batch)
            jax.block_until_ready(jax.tree.leaves(r.params)[0])
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts)), r

    t_un, r_un = timed(1)
    t_b, r_b = timed(MAX_BATCH)
    speedup = t_un / t_b

    # live-vs-replay parity: the recorded batched session as ONE
    # compiled trace from the same seeded init
    rep = ing.replay_session(r_b.session, client_plane=plane, params0=p0)
    parity = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                     - np.asarray(b, np.float32))))
                 for a, b in zip(jax.tree.leaves(r_b.params),
                                 jax.tree.leaves(rep.params)))

    # wall-clock open-loop context: p50/p99 under a live Poisson load
    rt = ing.run_ingest(
        task, cfg(MAX_BATCH).replace(iterations=RT_EVENTS), fleet=fleet,
        client_plane=plane, params0=p0,
        arrivals=ing.poisson_arrivals(RT_RATE, RT_EVENTS, M=M, seed=seed),
        realtime=True)
    lat = rt.latency

    emit("ingest.serve.unbatched", t_un * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_un:.1f} events/s (max_batch=1, one launch "
         "per event)")
    emit("ingest.serve.batched", t_b * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_b:.1f} events/s (max_batch={MAX_BATCH}); "
         f"{speedup:.2f}x unbatched; parity {parity:.2e}; "
         f"{r_b.stats['launches']} launches / "
         f"{r_b.stats['batches']} micro-batches")
    emit("ingest.serve.open_loop_p99", lat["p99"] * 1e6,
         f"p50 {lat['p50'] * 1e3:.1f}ms p99 {lat['p99'] * 1e3:.1f}ms at "
         f"{RT_RATE:.0f}/s offered ({lat['events_per_s']:.1f} served), "
         "wall clock (context)")
    save_result("ingest", {
        "model": "paper_cnn_cpu_budget", "M": M, "K": K,
        "local_batches": LOCAL_BATCHES, "batch_size": BATCH_SIZE,
        "iterations": ITERATIONS, "max_batch": MAX_BATCH, "seed": seed,
        "mode": plane.engine.mode,
        "unbatched_s": t_un, "batched_s": t_b,
        "events_per_s_unbatched": ITERATIONS / t_un,
        "events_per_s_batched": ITERATIONS / t_b,
        "batched_launches": r_b.stats["launches"],
        "batched_micro_batches": r_b.stats["batches"],
        "p50_ms": lat["p50"] * 1e3, "p99_ms": lat["p99"] * 1e3,
        "open_loop_events_per_s": lat["events_per_s"],
        "open_loop_rate": RT_RATE,
        "speedup": speedup,
        "parity_max_abs_diff": parity,
    })


def main() -> None:
    bench_ingest()


if __name__ == "__main__":
    main()
