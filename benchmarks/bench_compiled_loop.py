"""Compiled-loop benchmark: end-to-end ``run_afl`` events/s with the
whole-run event-trace compiler (docs/DESIGN.md §7) vs the per-window
fleet-plane loop (§4), at M=64 clients.

window   — the PR-2/3 loop: the scheduler generator walks on the host,
           every event dispatches a row-blend launch, every
           uploader-repeat flushes a vmapped retrain window; O(E +
           windows) jitted dispatches + the per-event Python (coefficient
           math, queueing, staging) interleaved with device work.
compiled — the scheduler timeline and all β_j precomputed once on the
           host, batches staged once, and the WHOLE run executed as
           O(#buckets) donated ``lax.scan`` launches; the only per-event
           cost left is the scan step itself.

The model is the paper-CNN geometry at CPU-budget width with K=1 local
iteration × 2 minibatches per event — deliberately at the dispatch-bound
end of the spectrum, because *that* is what the compiler deletes: the
per-event host hop.  (The windowed loop's remaining per-event cost here
is ~Python + jit-call dispatch; at K·B=32 per event both loops are
conv-compute-bound on this 2-core container and the ratio approaches 1
— same regime argument as bench_client_plane.py, see DESIGN.md §5.)  On
dispatch-bound accelerator hosts every AFL configuration sits in this
regime, and the acceptance floor (≥1.3x on the recorded host, per the
PR-2/3 host-keyed convention) should be re-recorded there along with
the baseline.

Also records compiled/window parity on the final params (gated ≤1e-5 by
``benchmarks/check_regression.py``) and the compiled run's launch count
(context — the "one scan, not one hop per window" signal).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, emit, save_result

M = 64
K = 1                      # local iterations per upload
LOCAL_BATCHES = 2          # minibatches per local iteration
BATCH_SIZE = 1
ITERATIONS = 256           # upload events per timed run


def _run(fleet, p0, plane, compiled: bool):
    from repro.core.afl import run_afl
    return run_afl(p0, fleet, None, algorithm="csmaafl",
                   iterations=ITERATIONS, tau_u=0.1, tau_d=0.1, gamma=0.4,
                   client_plane=plane, compiled_loop=compiled)


def bench_compiled_loop() -> None:
    import jax

    from repro.configs.paper_cnn import CNNConfig
    from repro.core.scheduler import make_fleet
    from repro.core.tasks import CNNTask

    seed = bench_seed()
    cnn_cfg = CNNConfig(conv1=2, conv2=4, fc=16)   # CPU-budget width
    task = CNNTask(iid=True, num_clients=M, train_n=2048, test_n=128,
                   batch_size=BATCH_SIZE,
                   local_batches_per_step=LOCAL_BATCHES,
                   cnn_cfg=cnn_cfg, seed=seed)
    fleet = make_fleet(M, tau=1.0, hetero_a=4.0,
                       samples_per_client=task.num_samples(),
                       adaptive=False, base_local_steps=K, seed=seed)
    p0 = task.init_params()
    plane = task.client_plane(fleet)

    def timed(compiled):
        # warmup run compiles every program variant, then one timed run
        # (an end-to-end run IS the median of ITERATIONS events)
        r = _run(fleet, p0, plane, compiled)
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        t0 = time.perf_counter()
        r = _run(fleet, p0, plane, compiled)
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        return time.perf_counter() - t0, r

    t_win, r_win = timed(False)
    t_cmp, r_cmp = timed(True)
    ev_win = ITERATIONS / t_win
    ev_cmp = ITERATIONS / t_cmp
    speedup = t_win / t_cmp
    parity = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                     - np.asarray(b, np.float32))))
                 for a, b in zip(jax.tree.leaves(r_cmp.params),
                                 jax.tree.leaves(r_win.params)))
    emit("compiled_loop.run_afl.per_window", t_win * 1e6 / ITERATIONS,
         f"{ev_win:.1f} events/s (host hop per event/window)")
    emit("compiled_loop.run_afl.compiled", t_cmp * 1e6 / ITERATIONS,
         f"{ev_cmp:.1f} events/s; {speedup:.1f}x vs per-window; "
         f"{r_cmp.stats['launches']} launches; parity {parity:.2e}")
    save_result("compiled_loop", {
        "model": "paper_cnn_cpu_budget", "M": M, "K": K,
        "local_batches": LOCAL_BATCHES, "batch_size": BATCH_SIZE,
        "iterations": ITERATIONS, "seed": seed,
        "mode": plane.engine.mode,
        "window_s": t_win, "compiled_s": t_cmp,
        "events_per_s_window": ev_win, "events_per_s_compiled": ev_cmp,
        "compiled_launches": r_cmp.stats["launches"],
        "compiled_variants": r_cmp.stats["variants"],
        "speedup": speedup, "parity_max_abs_diff": parity,
    })


def main() -> None:
    bench_compiled_loop()


if __name__ == "__main__":
    main()
