"""Roofline summary benchmark: renders the §Roofline table from the
dry-run records in experiments/dryrun (run ``python -m repro.launch.dryrun
--all --roofline`` first; this bench only reads)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def main() -> None:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*__single.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        t = rec["roofline"]["terms_full"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "t_compute_s": t["t_compute_s"],
            "t_memory_s": t["t_memory_s"],
            "t_collective_s": t["t_collective_s"],
            "dominant": t["dominant"],
            "useful_flops_ratio": rec["roofline"]["useful_flops_ratio"],
        })
        emit(f"roofline.{rec['arch']}.{rec['shape']}",
             t[f"t_{t['dominant']}_s"] * 1e6,
             f"dominant={t['dominant']};useful="
             f"{rec['roofline']['useful_flops_ratio']:.2f}")
    if rows:
        save_result("roofline_table", {"rows": rows})
    else:
        print("roofline.no_records,0,run dryrun --all --roofline first")


if __name__ == "__main__":
    main()
