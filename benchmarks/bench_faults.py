"""Fault-injection staging benchmark (docs/DESIGN.md §9): the cost of
realizing a fault model over the AFL timeline, relative to staging the
clean trace.

``compile_afl_trace`` is pure host-side control plane; the fault
transform (``core/faults.py``) adds four vectorized draw/filter passes,
the availability interval algebra and the drop-aware model-version
replay on top.  The whole point of keeping it a trace TRANSFORM (same
event skeleton, β=1 no-op slots) is that degraded runs stage and
execute with the clean run's launch structure — so the gated metric is

    speedup = clean_staging_s / faulty_staging_s

which must stay ≥ 1/1.3 (the ISSUE's "faulty staging ≤ 1.3x clean"
acceptance bound; floor 0.75 leaves measurement headroom).  A collapse
(per-event Python in the realization, per-client re-simulation) lands
far below it.

Also records the determinism parity: two faulty compiles under one
fault seed must produce BIT-IDENTICAL β streams (max abs diff 0.0,
gated ≤1e-5 by ``benchmarks/check_regression.py``), plus the realized
drop rate as context.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, emit, save_result

M = 64
ITERATIONS = 4096          # upload events per staged timeline
REPS = 7
PRESET = "diurnal20"


def _stage(fleet, faults, seed):
    from repro.core.event_trace import compile_afl_trace
    return compile_afl_trace(fleet, algorithm="csmaafl",
                             iterations=ITERATIONS, tau_u=0.1, tau_d=0.1,
                             gamma=0.4, seed=seed, faults=faults)


def bench_faults() -> None:
    from repro.core import faults as flt
    from repro.core.scheduler import make_fleet

    seed = bench_seed()
    fleet = make_fleet(M, tau=1.0, hetero_a=4.0,
                       samples_per_client=[100] * M, seed=seed)

    def timed(faults):
        _stage(fleet, faults, seed)            # warmup (imports, caches)
        ts = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            tr = _stage(fleet, faults, seed)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts)), tr

    t_clean, tr_clean = timed(None)
    t_fault, tr_fault = timed(PRESET)
    speedup = t_clean / t_fault
    # determinism: a second compile under the same fault seed must be
    # bit-identical (the four-path parity contract rests on this)
    tr_again = _stage(fleet, PRESET, seed)
    parity = float(np.max(np.abs(tr_fault.betas - tr_again.betas)))
    if not np.array_equal(tr_fault.dropped, tr_again.dropped):
        parity = 1.0                           # fail the gate loudly
    stats = flt.trace_stats(tr_fault)
    emit("faults.stage.clean", t_clean * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_clean:.0f} events/s staged")
    emit("faults.stage.faulty", t_fault * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_fault:.0f} events/s; {1 / speedup:.2f}x clean "
         f"staging cost; drop_rate={stats['drop_rate']:.3f}; "
         f"parity {parity:.1e}")
    save_result("faults", {
        "model": "staging_only", "M": M, "iterations": ITERATIONS,
        "preset": PRESET, "seed": seed,
        "clean_s": t_clean, "faulty_s": t_fault,
        "events_per_s_clean": ITERATIONS / t_clean,
        "events_per_s_faulty": ITERATIONS / t_fault,
        "drop_rate": stats["drop_rate"],
        "fault_drops": stats["fault_drops"],
        "contribution_gini": stats["contribution_gini"],
        "speedup": speedup, "parity_max_abs_diff": parity,
    })


def main() -> None:
    bench_faults()


if __name__ == "__main__":
    main()
