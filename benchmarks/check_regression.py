"""Bench regression gates (aggregation engine + client plane + sharded
plane + compiled event loop) — CI-friendly.

Compares the latest results under ``experiments/bench/`` (written by
``benchmarks/bench_aggregation.py`` / ``bench_client_plane.py`` /
``bench_sharded_plane.py``) against the committed baselines in
``benchmarks/baseline_*.json`` and exits nonzero when a gated speedup
regresses by more than ``THRESHOLD``x, drops below its acceptance floor,
or a recorded parity exceeds its bound.

The watched metrics are SAME-RUN ratios, not absolute microseconds:
wall-clock medians swing ~2x with machine load on a shared CPU, while the
two variants of each gate are timed back-to-back in one process, so their
ratio isolates the code path.  A >1.3x drop in a ratio is the "someone
re-introduced per-leaf/per-minibatch dispatch" (or "sharding started
gathering the fleet") class of regression, not noise.

The ratios are still PER-ENVIRONMENT, so baselines and floors are keyed
by HOSTNAME: a baseline recorded on this repo's container says nothing
about a fresh CI runner.  When the current host doesn't match the
baseline's ``host`` field the gate WARNS and reports ``skipped-unknown-
host`` instead of false-failing — re-record the baseline on the new host
(run the bench, copy ``experiments/bench/*.json`` over the baseline) to
arm it there.

Exit codes (distinct so CI can tell the failure classes apart):

* 0 — every requested gate passed (or was skipped for an unknown host)
* 1 — at least one REGRESSION (speedup drop / floor / parity)
* 2 — invocation or config error (unknown gate, config-key mismatch)
* 3 — missing baseline or missing bench result

Every run also writes a machine-readable ``gate_report.json`` (default
``experiments/bench/gate_report.json``, override with ``--report``) with
per-gate speedup, floor, parity, and pass/fail status.

Usage:  python -m benchmarks.check_regression [--threshold 1.3]
            [--which aggregation,client_plane,sharded_plane,compiled_loop]
            [--report path/to/gate_report.json]
        python -m benchmarks.run --only aggregation,client_plane --gate
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import sys

HERE = os.path.dirname(__file__)
LATEST_DIR = os.path.join(HERE, "..", "experiments", "bench")
THRESHOLD = 1.3
DEFAULT_REPORT = os.path.join(LATEST_DIR, "gate_report.json")

EXIT_OK = 0
EXIT_REGRESSION = 1
EXIT_USAGE = 2
EXIT_MISSING = 3

GATES = {
    "aggregation": {
        "baseline": os.path.join(HERE, "baseline_aggregation.json"),
        "latest": os.path.join(LATEST_DIR, "aggregation_fused.json"),
        "config_keys": ("mode", "trunk_k", "params", "model"),
        "context_keys": ("naive_us", "fused_us", "fused_single_us"),
        "floor": 3.0,
        "rerun_hint": "python -m benchmarks.run --only aggregation",
    },
    "client_plane": {
        "baseline": os.path.join(HERE, "baseline_client_plane.json"),
        "latest": os.path.join(LATEST_DIR, "client_plane.json"),
        "config_keys": ("mode", "model", "M", "K", "local_batches",
                        "iterations", "seed"),
        "context_keys": ("off_s", "on_s", "events_per_s_on"),
        # the floor is the "plane-on degenerated to per-minibatch" signal
        # for THIS host: the repo's 2-core CPU container is conv-compute-
        # bound (jit dispatch is ~3us), which caps the honest end-to-end
        # win near ~2x — the ISSUE's 5x target assumes a dispatch-bound
        # accelerator host and should be re-floored when the baseline is
        # re-recorded there (see bench_client_plane.py's docstring).
        "floor": 1.4,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only client_plane",
    },
    "sharded_plane": {
        "baseline": os.path.join(HERE, "baseline_sharded_plane.json"),
        "latest": os.path.join(LATEST_DIR, "sharded_plane.json"),
        "config_keys": ("mode", "model", "M", "K", "local_batches",
                        "iterations", "devices", "seed"),
        "context_keys": ("single_s", "sharded_s", "events_per_s_sharded"),
        # 8 SIMULATED devices time-share this container's 2 cores, so the
        # honest sharded/single ratio here is ~1x; the floor guards the
        # "sharding started gathering the fleet / recompiling per event"
        # collapse, not a speedup.  Re-floor on a real multi-chip mesh.
        "floor": 0.5,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only sharded_plane",
    },
    "compiled_loop": {
        "baseline": os.path.join(HERE, "baseline_compiled_loop.json"),
        "latest": os.path.join(LATEST_DIR, "compiled_loop.json"),
        "config_keys": ("mode", "model", "M", "K", "local_batches",
                        "iterations", "seed"),
        "context_keys": ("window_s", "compiled_s",
                         "events_per_s_compiled", "compiled_launches"),
        # whole-run event-trace compiler vs the per-window plane loop
        # (DESIGN.md §7) at the dispatch-light K·B=2 configuration; this
        # 2-core container measures ~1.6x (the scan still pays XLA:CPU's
        # while-loop path on the conv body), so the floor sits at the
        # ISSUE's 1.3x acceptance bound — the "compiled loop degenerated
        # to per-event dispatch / started recompiling per segment"
        # signal.  On dispatch-bound accelerator hosts the same
        # mechanism is worth far more; re-record baseline + floor there.
        "floor": 1.3,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only compiled_loop",
    },
}


def check_gate(name: str, threshold: float = THRESHOLD):
    """Returns (exit_code, record) for one gate; record feeds the
    machine-readable gate report."""
    g = GATES[name]
    rec = {"gate": name, "floor": g["floor"],
           "parity_bound": g.get("parity_bound"),
           "threshold": threshold, "host": socket.gethostname()}

    def fail(code, status, msg):
        print(f"gate[{name}]: {msg}", file=sys.stderr)
        rec["status"] = status
        return code, rec

    if not os.path.exists(g["baseline"]):
        return fail(EXIT_MISSING, "missing-baseline",
                    f"no baseline at {g['baseline']} — run the bench and "
                    "commit its result as the baseline")
    if not os.path.exists(g["latest"]):
        return fail(EXIT_MISSING, "missing-latest",
                    f"no bench result at {g['latest']} — run "
                    f"`{g['rerun_hint']}` first")
    with open(g["baseline"]) as f:
        base = json.load(f)
    with open(g["latest"]) as f:
        latest = json.load(f)
    rec["baseline_host"] = base.get("host")

    # hostname keying: ratios (and their floors) are per-environment, so
    # an unrecorded host must warn, not false-fail (CI runners churn)
    host = socket.gethostname()
    if base.get("host") is not None and base["host"] != host:
        print(f"gate[{name}]: WARNING baseline was recorded on host "
              f"{base['host']!r} but this is {host!r} — skipping the gate "
              "(re-record the baseline on this host to arm it)",
              file=sys.stderr)
        rec["status"] = "skipped-unknown-host"
        return EXIT_OK, rec

    # the ratio is only comparable for the same configuration: a baseline
    # recorded in xla mode on CPU says nothing about kernel mode on TPU
    for key in g["config_keys"]:
        if base.get(key) != latest.get(key):
            return fail(EXIT_USAGE, "config-mismatch",
                        f"config mismatch on '{key}' (baseline "
                        f"{base.get(key)!r} vs latest {latest.get(key)!r})"
                        " — re-record the baseline for this configuration")
    # context: absolute medians (load-sensitive, never gated on)
    rec["context"] = {}
    for key in g["context_keys"]:
        if key in base and key in latest:
            rec["context"][key] = {"baseline": base[key],
                                   "latest": latest[key]}
            print(f"gate[{name}]: (context) {key}: baseline "
                  f"{base[key]:.6g} -> latest {latest[key]:.6g}")
    # gated: the same-run speedup
    if "speedup" not in base or "speedup" not in latest:
        return fail(EXIT_USAGE, "config-mismatch",
                    "speedup missing from baseline or latest")
    rc = EXIT_OK
    b_sp, l_sp = float(base["speedup"]), float(latest["speedup"])
    ratio = b_sp / max(l_sp, 1e-9)
    rec.update(baseline_speedup=b_sp, speedup=l_sp, drop_ratio=ratio)
    status = "OK" if ratio <= threshold else "REGRESSION"
    print(f"gate[{name}]: speedup: baseline {b_sp:.1f}x -> latest "
          f"{l_sp:.1f}x ({ratio:.2f}x drop) {status}")
    if ratio > threshold:
        rc = EXIT_REGRESSION
    if l_sp < g["floor"]:
        print(f"gate[{name}]: speedup {l_sp:.1f}x < {g['floor']:.1f}x "
              "floor REGRESSION")
        rc = EXIT_REGRESSION
    # gated: numerical parity of the two variants (where recorded)
    pk = g.get("parity_key")
    if pk is not None and pk in latest:
        parity = float(latest[pk])
        bound = g["parity_bound"]
        ok = parity <= bound
        rec["parity"] = parity
        print(f"gate[{name}]: parity: {parity:.2e} "
              f"(bound {bound:.0e}) {'OK' if ok else 'REGRESSION'}")
        if not ok:
            rc = EXIT_REGRESSION
    rec["status"] = "pass" if rc == EXIT_OK else "regression"
    return rc, rec


def combine_codes(codes) -> int:
    """Regression dominates, then usage errors, then missing artifacts."""
    for code in (EXIT_REGRESSION, EXIT_USAGE, EXIT_MISSING):
        if code in codes:
            return code
    return EXIT_OK


def write_report(path: str, records, rc: int, threshold: float) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    report = {"host": socket.gethostname(), "threshold": threshold,
              "exit_code": rc,
              "gates": {r["gate"]: r for r in records}}
    with open(path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    print(f"gate: report written to {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    ap.add_argument("--which",
                    default=",".join(GATES),
                    help="comma list of gates: " + ",".join(GATES))
    ap.add_argument("--report", default=DEFAULT_REPORT,
                    help="machine-readable per-gate report path "
                         "('' disables)")
    args = ap.parse_args(argv)
    codes, records = [], []
    for name in args.which.split(","):
        name = name.strip()
        if name not in GATES:
            print(f"gate: unknown gate '{name}'", file=sys.stderr)
            return EXIT_USAGE
        rc, rec = check_gate(name, args.threshold)
        codes.append(rc)
        records.append(rec)
    rc = combine_codes(codes)
    if args.report:
        write_report(args.report, records, rc, args.threshold)
    return rc


if __name__ == "__main__":
    sys.exit(main())
