"""Aggregation-bench regression gate.

Compares the latest ``experiments/bench/aggregation_fused.json`` (written
by ``benchmarks/bench_aggregation.py``) against the committed baseline in
``benchmarks/baseline_aggregation.json`` and exits nonzero when the
fused-vs-naive speedup regresses by more than ``THRESHOLD``x (or drops
below the 3x acceptance floor).

The watched metric is the SAME-RUN ratio, not absolute microseconds:
wall-clock medians swing ~2x with machine load on a shared CPU, while
naive and fused are timed back-to-back in one process, so their ratio
isolates the aggregation path.  A >1.3x drop in that ratio is the
"someone re-introduced per-leaf dispatch" class of regression, not
noise.  Absolute timings are printed as context only.

The committed baseline is still PER-ENVIRONMENT: the ratio isolates
load, not hardware (a different CPU's fusion win, or kernel mode on
TPU, legitimately shifts it).  The gate refuses mismatched
configurations (exit 2) and expects the baseline to be re-recorded when
the benchmark host changes: `make bench-agg`, then copy
``experiments/bench/aggregation_fused.json`` over the baseline.

Usage:  python -m benchmarks.check_regression [--threshold 1.3]
        python -m benchmarks.run --only aggregation --gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(__file__)
BASELINE = os.path.join(HERE, "baseline_aggregation.json")
LATEST = os.path.join(HERE, "..", "experiments", "bench",
                      "aggregation_fused.json")
THRESHOLD = 1.3
SPEEDUP_FLOOR = 3.0          # the PR's acceptance criterion


def check(baseline_path: str = BASELINE, latest_path: str = LATEST,
          threshold: float = THRESHOLD) -> int:
    if not os.path.exists(baseline_path):
        print(f"gate: no baseline at {baseline_path} — run the bench and "
              "commit its aggregation_fused.json as the baseline",
              file=sys.stderr)
        return 2
    if not os.path.exists(latest_path):
        print(f"gate: no bench result at {latest_path} — run "
              "`python -m benchmarks.run --only aggregation` first",
              file=sys.stderr)
        return 2
    with open(baseline_path) as f:
        base = json.load(f)
    with open(latest_path) as f:
        latest = json.load(f)
    rc = 0
    # the ratio is only comparable for the same configuration: a baseline
    # recorded in xla mode on CPU says nothing about kernel mode on TPU
    for key in ("mode", "trunk_k", "params", "model"):
        if base.get(key) != latest.get(key):
            print(f"gate: config mismatch on '{key}' (baseline "
                  f"{base.get(key)!r} vs latest {latest.get(key)!r}) — "
                  "re-record the baseline for this configuration",
                  file=sys.stderr)
            return 2
    # context: absolute medians (load-sensitive, never gated on)
    for key in ("naive_us", "fused_us", "fused_single_us"):
        if key in base and key in latest:
            print(f"gate: (context) {key}: baseline {base[key]:.1f}us -> "
                  f"latest {latest[key]:.1f}us")
    # gated: the same-run fused-vs-naive speedup
    if "speedup" not in base or "speedup" not in latest:
        print("gate: speedup missing from baseline or latest result",
              file=sys.stderr)
        return 2
    b_sp, l_sp = float(base["speedup"]), float(latest["speedup"])
    ratio = b_sp / max(l_sp, 1e-9)
    status = "OK" if ratio <= threshold else "REGRESSION"
    print(f"gate: speedup: baseline {b_sp:.1f}x -> latest {l_sp:.1f}x "
          f"({ratio:.2f}x drop) {status}")
    if ratio > threshold:
        rc = 1
    if l_sp < SPEEDUP_FLOOR:
        print(f"gate: fused speedup {l_sp:.1f}x < {SPEEDUP_FLOOR:.1f}x "
              "floor REGRESSION")
        rc = 1
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--latest", default=LATEST)
    args = ap.parse_args(argv)
    return check(args.baseline, args.latest, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
