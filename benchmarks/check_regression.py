"""Bench regression gates (aggregation engine + client plane).

Compares the latest results under ``experiments/bench/`` (written by
``benchmarks/bench_aggregation.py`` / ``bench_client_plane.py``) against
the committed baselines in ``benchmarks/baseline_*.json`` and exits
nonzero when a gated speedup regresses by more than ``THRESHOLD``x or
drops below its acceptance floor.

The watched metrics are SAME-RUN ratios, not absolute microseconds:
wall-clock medians swing ~2x with machine load on a shared CPU, while the
two variants of each gate are timed back-to-back in one process, so their
ratio isolates the code path.  A >1.3x drop in a ratio is the "someone
re-introduced per-leaf/per-minibatch dispatch" class of regression, not
noise.  Absolute timings are printed as context only.

Gates:

* ``aggregation``  — fused flat-buffer engine vs naive per-leaf blend
  (floor 3x, PR 1's acceptance criterion).
* ``client_plane`` — fused fleet plane vs per-minibatch run_afl
  (floor 5x + parity ≤1e-5, PR 2's acceptance criterion).

The committed baselines are still PER-ENVIRONMENT: the ratio isolates
load, not hardware.  Each gate refuses mismatched configurations (exit 2)
and expects its baseline to be re-recorded when the benchmark host
changes: run the bench, then copy the ``experiments/bench/*.json`` over
the baseline.

Usage:  python -m benchmarks.check_regression [--threshold 1.3]
                                              [--which aggregation,client_plane]
        python -m benchmarks.run --only aggregation,client_plane --gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(__file__)
LATEST_DIR = os.path.join(HERE, "..", "experiments", "bench")
THRESHOLD = 1.3

GATES = {
    "aggregation": {
        "baseline": os.path.join(HERE, "baseline_aggregation.json"),
        "latest": os.path.join(LATEST_DIR, "aggregation_fused.json"),
        "config_keys": ("mode", "trunk_k", "params", "model"),
        "context_keys": ("naive_us", "fused_us", "fused_single_us"),
        "floor": 3.0,
        "rerun_hint": "python -m benchmarks.run --only aggregation",
    },
    "client_plane": {
        "baseline": os.path.join(HERE, "baseline_client_plane.json"),
        "latest": os.path.join(LATEST_DIR, "client_plane.json"),
        "config_keys": ("mode", "model", "M", "K", "local_batches",
                        "iterations"),
        "context_keys": ("off_s", "on_s", "events_per_s_on"),
        # the floor is the "plane-on degenerated to per-minibatch" signal
        # for THIS host: the repo's 2-core CPU container is conv-compute-
        # bound (jit dispatch is ~3us), which caps the honest end-to-end
        # win near ~2x — the ISSUE's 5x target assumes a dispatch-bound
        # accelerator host and should be re-floored when the baseline is
        # re-recorded there (see bench_client_plane.py's docstring).
        "floor": 1.4,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only client_plane",
    },
}


def check_gate(name: str, threshold: float = THRESHOLD) -> int:
    g = GATES[name]
    if not os.path.exists(g["baseline"]):
        print(f"gate[{name}]: no baseline at {g['baseline']} — run the "
              "bench and commit its result as the baseline",
              file=sys.stderr)
        return 2
    if not os.path.exists(g["latest"]):
        print(f"gate[{name}]: no bench result at {g['latest']} — run "
              f"`{g['rerun_hint']}` first", file=sys.stderr)
        return 2
    with open(g["baseline"]) as f:
        base = json.load(f)
    with open(g["latest"]) as f:
        latest = json.load(f)
    rc = 0
    # the ratio is only comparable for the same configuration: a baseline
    # recorded in xla mode on CPU says nothing about kernel mode on TPU
    for key in g["config_keys"]:
        if base.get(key) != latest.get(key):
            print(f"gate[{name}]: config mismatch on '{key}' (baseline "
                  f"{base.get(key)!r} vs latest {latest.get(key)!r}) — "
                  "re-record the baseline for this configuration",
                  file=sys.stderr)
            return 2
    # context: absolute medians (load-sensitive, never gated on)
    for key in g["context_keys"]:
        if key in base and key in latest:
            print(f"gate[{name}]: (context) {key}: baseline "
                  f"{base[key]:.6g} -> latest {latest[key]:.6g}")
    # gated: the same-run speedup
    if "speedup" not in base or "speedup" not in latest:
        print(f"gate[{name}]: speedup missing from baseline or latest",
              file=sys.stderr)
        return 2
    b_sp, l_sp = float(base["speedup"]), float(latest["speedup"])
    ratio = b_sp / max(l_sp, 1e-9)
    status = "OK" if ratio <= threshold else "REGRESSION"
    print(f"gate[{name}]: speedup: baseline {b_sp:.1f}x -> latest "
          f"{l_sp:.1f}x ({ratio:.2f}x drop) {status}")
    if ratio > threshold:
        rc = 1
    if l_sp < g["floor"]:
        print(f"gate[{name}]: speedup {l_sp:.1f}x < {g['floor']:.1f}x "
              "floor REGRESSION")
        rc = 1
    # gated: numerical parity of the two variants (where recorded)
    pk = g.get("parity_key")
    if pk is not None and pk in latest:
        parity = float(latest[pk])
        bound = g["parity_bound"]
        ok = parity <= bound
        print(f"gate[{name}]: parity: {parity:.2e} "
              f"(bound {bound:.0e}) {'OK' if ok else 'REGRESSION'}")
        if not ok:
            rc = 1
    return rc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    ap.add_argument("--which", default="aggregation,client_plane",
                    help="comma list of gates: " + ",".join(GATES))
    args = ap.parse_args(argv)
    rc = 0
    for name in args.which.split(","):
        name = name.strip()
        if name not in GATES:
            print(f"gate: unknown gate '{name}'", file=sys.stderr)
            return 2
        rc = max(rc, check_gate(name, args.threshold))
    return rc


if __name__ == "__main__":
    sys.exit(main())
