"""Bench regression gates (aggregation engine + client plane + sharded
plane + compiled event loop + sweep plane + fault staging + recovery
plane + streaming ingest) — CI-enforcing.

Compares the latest results under ``experiments/bench/local/`` (written
by the gated benches; gitignored) against the committed baselines in
``benchmarks/baseline_*.json`` and exits nonzero when a gated speedup
regresses by more than ``THRESHOLD``x, drops below its acceptance floor,
or a recorded parity exceeds its bound.

The watched metrics are SAME-RUN ratios, not absolute microseconds:
wall-clock medians swing ~2x with machine load on a shared CPU, while the
two variants of each gate are timed back-to-back in one process, so their
ratio isolates the code path.  A >1.3x drop in a ratio is the "someone
re-introduced per-leaf/per-minibatch/per-run dispatch" (or "sharding
started gathering the fleet") class of regression, not noise.

The ratios are still PER-ENVIRONMENT, so baselines and floors are keyed
by a HOST KEY:

* ``REPRO_BENCH_HOST_KEY`` env, when set (CI pins this);
* else ``github-runner`` when running under GitHub Actions — runner
  hostnames churn per job, but the fleet is homogeneous enough that one
  shared key with conservative floors gates real regressions;
* else the machine hostname.

A baseline file holds the recording host's result at top level plus an
optional ``"hosts"`` map of per-key records (each may carry its own
``floor``).  When the current key matches neither, the gate WARNS and
reports ``skipped-unknown-host`` — unless ``--enforce`` (or
``REPRO_GATE_ENFORCE=1``) is set, in which case an unknown host is a
FAILURE (exit 3): CI must gate, not skip.  ``make bench-record`` reruns
the gated benches and folds the fresh results into the baselines under
the current host key (``--record-baselines``).

Exit codes (distinct so CI can tell the failure classes apart):

* 0 — every requested gate passed (or was skipped for an unknown host
      in non-enforcing mode)
* 1 — at least one REGRESSION (speedup drop / floor / parity)
* 2 — invocation or config error (unknown gate, config-key mismatch)
* 3 — missing baseline or missing bench result (incl. unknown host
      under --enforce)

Every run also writes a machine-readable ``gate_report.json`` (default
``experiments/bench/local/gate_report.json``, override with
``--report``) with per-gate speedup, floor, parity, and pass/fail.

Usage:  python -m benchmarks.check_regression [--threshold 1.3]
            [--which aggregation,...,sweep_plane] [--enforce]
            [--report path] [--record-baselines]
        python -m benchmarks.run --only aggregation,client_plane --gate
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import sys

HERE = os.path.dirname(__file__)
LATEST_DIR = os.path.join(HERE, "..", "experiments", "bench", "local")
THRESHOLD = 1.3
DEFAULT_REPORT = os.path.join(LATEST_DIR, "gate_report.json")

EXIT_OK = 0
EXIT_REGRESSION = 1
EXIT_USAGE = 2
EXIT_MISSING = 3

GATES = {
    "aggregation": {
        "baseline": os.path.join(HERE, "baseline_aggregation.json"),
        "latest": os.path.join(LATEST_DIR, "aggregation_fused.json"),
        "config_keys": ("mode", "trunk_k", "params", "model"),
        "context_keys": ("naive_us", "fused_us", "fused_single_us"),
        "floor": 3.0,
        # the naive per-leaf comparator's wall time swings >2x with
        # machine load on the shared container (5.9x..19.8x measured in
        # one day), so the drop-ratio check needs a wider budget here —
        # the FLOOR is this gate's real "engine collapsed to per-leaf"
        # signal (a real collapse lands at ~1x, far below 3.0)
        "drop_threshold": 3.0,
        "rerun_hint": "python -m benchmarks.run --only aggregation",
    },
    "client_plane": {
        "baseline": os.path.join(HERE, "baseline_client_plane.json"),
        "latest": os.path.join(LATEST_DIR, "client_plane.json"),
        "config_keys": ("mode", "model", "M", "K", "local_batches",
                        "iterations", "seed"),
        "context_keys": ("off_s", "on_s", "events_per_s_on"),
        # the floor is the "plane-on degenerated to per-minibatch" signal
        # for THIS host: the repo's 2-core CPU container is conv-compute-
        # bound (jit dispatch is ~3us), which caps the honest end-to-end
        # win near ~2x — the ISSUE's 5x target assumes a dispatch-bound
        # accelerator host and should be re-floored when the baseline is
        # re-recorded there (see bench_client_plane.py's docstring).
        "floor": 1.4,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only client_plane",
    },
    "sharded_plane": {
        "baseline": os.path.join(HERE, "baseline_sharded_plane.json"),
        "latest": os.path.join(LATEST_DIR, "sharded_plane.json"),
        "config_keys": ("mode", "model", "M", "K", "local_batches",
                        "iterations", "devices", "seed"),
        "context_keys": ("single_s", "sharded_s", "events_per_s_sharded"),
        # 8 SIMULATED devices time-share this container's 2 cores, so the
        # honest sharded/single ratio here is ~1x; the floor guards the
        # "sharding started gathering the fleet / recompiling per event"
        # collapse, not a speedup.  Re-floor on a real multi-chip mesh.
        "floor": 0.5,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only sharded_plane",
    },
    "compiled_loop": {
        "baseline": os.path.join(HERE, "baseline_compiled_loop.json"),
        "latest": os.path.join(LATEST_DIR, "compiled_loop.json"),
        "config_keys": ("mode", "model", "M", "K", "local_batches",
                        "iterations", "seed"),
        "context_keys": ("window_s", "compiled_s",
                         "events_per_s_compiled", "compiled_launches"),
        # whole-run event-trace compiler vs the per-window plane loop
        # (DESIGN.md §7) at the dispatch-light K·B=2 configuration; this
        # 2-core container measures ~1.6x (the scan still pays XLA:CPU's
        # while-loop path on the conv body), so the floor sits at the
        # ISSUE's 1.3x acceptance bound — the "compiled loop degenerated
        # to per-event dispatch / started recompiling per segment"
        # signal.  On dispatch-bound accelerator hosts the same
        # mechanism is worth far more; re-record baseline + floor there.
        "floor": 1.3,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only compiled_loop",
    },
    "sweep_plane": {
        "baseline": os.path.join(HERE, "baseline_sweep_plane.json"),
        "latest": os.path.join(LATEST_DIR, "sweep_plane.json"),
        "config_keys": ("model", "M", "K", "local_batches", "toy_d",
                        "iterations_toy", "iterations_cnn", "runs_toy",
                        "runs_cnn", "seed"),
        "context_keys": ("events_per_s_sequential_toy",
                         "events_per_s_sweep_toy",
                         "events_per_s_sequential_cnn",
                         "events_per_s_sweep_cnn", "speedup_cnn",
                         "sweep_launches_toy", "sweep_launches_cnn"),
        # run-batched seeds x scenarios grid vs sequential compiled runs
        # (DESIGN.md §8) on the dispatch-light flat-toy grid WITH eval
        # curves (a convergence grid without histories is not the
        # paper's workload); ~2.6x on this 2-core container.  The
        # conv-bound paper-CNN grid is recorded as context (~1x here —
        # XLA:CPU conv is ~500us/sample and linear in batch); its parity
        # is what the parity bound gates.  The floor is the "sweep
        # degenerated to per-run host looping / per-run launches"
        # signal.
        "floor": 2.0,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only sweep_plane",
    },
    "faults": {
        "baseline": os.path.join(HERE, "baseline_faults.json"),
        "latest": os.path.join(LATEST_DIR, "faults.json"),
        "config_keys": ("model", "M", "iterations", "preset", "seed"),
        "context_keys": ("clean_s", "faulty_s", "events_per_s_faulty",
                         "drop_rate"),
        # fault realization is a host-side trace TRANSFORM (DESIGN.md
        # §9): staging a degraded timeline must cost ≤1.3x the clean
        # staging pass (the ISSUE's acceptance bound), i.e. the gated
        # clean/faulty ratio stays ≥ 1/1.3 — floor 0.75 leaves
        # measurement headroom.  A collapse to per-event Python or
        # per-client re-simulation lands far below.  The parity bound
        # gates determinism: two compiles under one fault seed must be
        # bit-identical (recorded as 0.0, or 1.0 on any mismatch).
        "floor": 0.75,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only faults",
    },
    "guards": {
        "baseline": os.path.join(HERE, "baseline_guards.json"),
        "latest": os.path.join(LATEST_DIR, "guards.json"),
        "config_keys": ("model", "M", "K", "local_batches", "iterations",
                        "autosave_every", "seed", "mode"),
        "context_keys": ("plain_s", "guarded_s", "autosave_s",
                         "events_per_s_plain"),
        # recovery-plane overhead (DESIGN.md §10): the in-scan guard is
        # a per-step f32 norm + where-mask cascade, so the gated
        # plain/guarded ratio must stay ≥ 1/1.15 (ISSUE: guarded ≤1.15x
        # unguarded; floor 0.87).  A collapse (guard verdicts syncing to
        # the host per event) lands near 0.1x.  The extra bound gates
        # autosave cost: durable segment-boundary saves every 64 events
        # must stay ≤5% of the plain run — per-event checkpointing or
        # in-scan serialization lands far above.  The parity bound gates
        # the guards-on clean-run BITWISE no-op contract (recorded 0.0).
        "floor": 0.87,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "extra_bounds": {"autosave_overhead": 0.05},
        "rerun_hint": "python -m benchmarks.run --only guards",
    },
    "ingest": {
        "baseline": os.path.join(HERE, "baseline_ingest.json"),
        "latest": os.path.join(LATEST_DIR, "ingest.json"),
        "config_keys": ("model", "M", "K", "local_batches", "batch_size",
                        "iterations", "max_batch", "seed", "mode"),
        "context_keys": ("unbatched_s", "batched_s",
                         "events_per_s_unbatched", "events_per_s_batched",
                         "batched_launches", "batched_micro_batches",
                         "p99_ms", "open_loop_events_per_s"),
        # streaming ingest (DESIGN.md §11): micro-batching the upload
        # stream vs per-event serving under a dense virtual-clock burst.
        # This conv-bound 2-core container measures ~1.4x (the blend /
        # launch overhead it amortizes is a minority of service time
        # here); a collapse — batch assembly falling back to per-event
        # launches, a host sync per admission, per-batch recompiles —
        # lands at ~1.0x, below the 1.15 floor.  The parity bound gates
        # the serving-vs-simulator contract: the live batched session
        # replayed offline as ONE compiled event trace must reproduce
        # the served model (micro-batch boundaries are value-invisible).
        "floor": 1.15,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "rerun_hint": "python -m benchmarks.run --only ingest",
    },
    "fleet_store": {
        "baseline": os.path.join(HERE, "baseline_fleet_store.json"),
        "latest": os.path.join(LATEST_DIR, "fleet_store.json"),
        "config_keys": ("model", "M", "P", "K", "local_batches",
                        "batch_size", "iterations", "stage_rows",
                        "stage_row_floats", "seed", "mode"),
        "context_keys": ("dense_s", "paged_s", "events_per_s_dense",
                         "events_per_s_paged", "paged_peak_device_rows",
                         "paged_prefetch_stalls", "paged_evictions"),
        # paged active-set pool (DESIGN.md §12): the small-M OVERHEAD
        # gate — dense_s/paged_s at M=64 with a tight P=16 pool, i.e.
        # the paged plane on exactly the workload where the dense plane
        # is optimal.  Slot bookkeeping + chunked (vs fleet-wide) launch
        # shapes cost a bounded constant factor; a collapse (a host sync
        # on the slot table per event, eviction write-back in the hot
        # loop, per-row device_put) lands far below the floor.  The
        # parity bound gates dense<->paged history parity; the extra
        # bound gates arena->device staging throughput (ms per staged
        # MB, so LOWER is better — an upper bound, not a floor).  This
        # host measures 0.73x-0.87x run-to-run (the chunked launches are
        # load-sensitive on the shared 2-core container), hence the
        # wider drop budget; a collapse lands at ~0.1-0.2x, far below
        # the floor either way.  Measured staging: ~1.1 ms/MB.
        "floor": 0.55,
        "drop_threshold": 1.6,
        "parity_key": "parity_max_abs_diff",
        "parity_bound": 1e-5,
        "extra_bounds": {"staging_ms_per_mb": 10.0},
        "rerun_hint": "python -m benchmarks.run --only fleet_store",
    },
}


def host_key() -> str:
    """Baseline/floor key for this environment (see module docstring)."""
    key = os.environ.get("REPRO_BENCH_HOST_KEY")
    if key:
        return key
    if os.environ.get("GITHUB_ACTIONS") == "true":
        return "github-runner"
    return socket.gethostname()


def enforcing(flag: bool = False) -> bool:
    return flag or os.environ.get("REPRO_GATE_ENFORCE", "") not in ("", "0")


def resolve_baseline(base: dict, key: str):
    """Pick the baseline record for ``key``: the top-level record when it
    was recorded under this key (or predates host keying), else the
    ``hosts`` map entry.  None = unrecorded host."""
    if base.get("host") in (None, key):
        return base
    rec = base.get("hosts", {}).get(key)
    return rec


def check_gate(name: str, threshold: float = THRESHOLD, *,
               enforce: bool = False):
    """Returns (exit_code, record) for one gate; record feeds the
    machine-readable gate report."""
    g = GATES[name]
    key = host_key()
    rec = {"gate": name, "floor": g["floor"],
           "parity_bound": g.get("parity_bound"),
           "threshold": threshold, "host": key,
           "hostname": socket.gethostname()}

    def fail(code, status, msg):
        print(f"gate[{name}]: {msg}", file=sys.stderr)
        rec["status"] = status
        return code, rec

    if not os.path.exists(g["baseline"]):
        return fail(EXIT_MISSING, "missing-baseline",
                    f"no baseline at {g['baseline']} — run the bench and "
                    "record it (`make bench-record`)")
    if not os.path.exists(g["latest"]):
        return fail(EXIT_MISSING, "missing-latest",
                    f"no bench result at {g['latest']} — run "
                    f"`{g['rerun_hint']}` first")
    with open(g["baseline"]) as f:
        base_file = json.load(f)
    with open(g["latest"]) as f:
        latest = json.load(f)
    rec["baseline_host"] = base_file.get("host")

    # host keying: ratios (and their floors) are per-environment; an
    # unrecorded host warns (local convenience) or fails (--enforce: CI
    # must gate, not silently skip)
    base = resolve_baseline(base_file, key)
    if base is None:
        if enforce:
            return fail(EXIT_MISSING, "unrecorded-host-enforced",
                        f"no baseline recorded for host key {key!r} "
                        f"(recorded: {base_file.get('host')!r} + "
                        f"{sorted(base_file.get('hosts', {}))}) and "
                        "--enforce is set — record one with "
                        "`make bench-record`")
        print(f"gate[{name}]: WARNING no baseline recorded for host key "
              f"{key!r} — skipping the gate (run `make bench-record` on "
              "this host to arm it)", file=sys.stderr)
        rec["status"] = "skipped-unknown-host"
        return EXIT_OK, rec
    floor = float(base.get("floor", g["floor"]))
    rec["floor"] = floor

    # the ratio is only comparable for the same configuration: a baseline
    # recorded in xla mode on CPU says nothing about kernel mode on TPU
    for cfg_key in g["config_keys"]:
        if base.get(cfg_key) != latest.get(cfg_key):
            return fail(EXIT_USAGE, "config-mismatch",
                        f"config mismatch on '{cfg_key}' (baseline "
                        f"{base.get(cfg_key)!r} vs latest "
                        f"{latest.get(cfg_key)!r}) — re-record the "
                        "baseline for this configuration")
    # context: absolute medians (load-sensitive, never gated on)
    rec["context"] = {}
    for cfg_key in g["context_keys"]:
        if cfg_key in base and cfg_key in latest:
            rec["context"][cfg_key] = {"baseline": base[cfg_key],
                                       "latest": latest[cfg_key]}
            print(f"gate[{name}]: (context) {cfg_key}: baseline "
                  f"{base[cfg_key]:.6g} -> latest {latest[cfg_key]:.6g}")
    # gated: the same-run speedup
    if "speedup" not in base or "speedup" not in latest:
        return fail(EXIT_USAGE, "config-mismatch",
                    "speedup missing from baseline or latest")
    rc = EXIT_OK
    b_sp, l_sp = float(base["speedup"]), float(latest["speedup"])
    ratio = b_sp / max(l_sp, 1e-9)
    # per-gate (or per-host-record) drop budget: gates whose comparator
    # is load-noisy widen it and lean on their floor instead
    thr = float(base.get("drop_threshold",
                         g.get("drop_threshold", threshold)))
    rec.update(baseline_speedup=b_sp, speedup=l_sp, drop_ratio=ratio,
               drop_threshold=thr)
    status = "OK" if ratio <= thr else "REGRESSION"
    print(f"gate[{name}]: speedup: baseline {b_sp:.1f}x -> latest "
          f"{l_sp:.1f}x ({ratio:.2f}x drop, budget {thr:.1f}x) {status}")
    if ratio > thr:
        rc = EXIT_REGRESSION
    if l_sp < floor:
        print(f"gate[{name}]: speedup {l_sp:.1f}x < {floor:.1f}x "
              "floor REGRESSION")
        rc = EXIT_REGRESSION
    # gated: numerical parity of the two variants (where recorded)
    pk = g.get("parity_key")
    if pk is not None and pk in latest:
        parity = float(latest[pk])
        bound = g["parity_bound"]
        ok = parity <= bound
        rec["parity"] = parity
        print(f"gate[{name}]: parity: {parity:.2e} "
              f"(bound {bound:.0e}) {'OK' if ok else 'REGRESSION'}")
        if not ok:
            rc = EXIT_REGRESSION
    # gated: additional recorded ratios with their own upper bounds
    # (e.g. the guards gate's autosave_overhead ≤ 0.05)
    for ek, eb in g.get("extra_bounds", {}).items():
        if ek not in latest:
            return fail(EXIT_USAGE, "config-mismatch",
                        f"gated value '{ek}' missing from {g['latest']} — "
                        f"re-run `{g['rerun_hint']}`")
        val = float(latest[ek])
        ok = val <= eb
        rec.setdefault("extra_bounds", {})[ek] = {"value": val,
                                                  "bound": eb}
        print(f"gate[{name}]: {ek}: {val:.4f} (bound {eb:g}) "
              f"{'OK' if ok else 'REGRESSION'}")
        if not ok:
            rc = EXIT_REGRESSION
    rec["status"] = "pass" if rc == EXIT_OK else "regression"
    return rc, rec


def record_baseline(name: str) -> int:
    """Fold the latest local result for ``name`` into its baseline file
    under the current host key (top level when the file was recorded
    under this key or doesn't exist yet; the ``hosts`` map otherwise).
    An existing per-key ``floor`` override is preserved."""
    g = GATES[name]
    key = host_key()
    if not os.path.exists(g["latest"]):
        print(f"record[{name}]: no bench result at {g['latest']} — run "
              f"`{g['rerun_hint']}` first", file=sys.stderr)
        return EXIT_MISSING
    with open(g["latest"]) as f:
        latest = json.load(f)
    latest["host"] = key
    base_file = {}
    if os.path.exists(g["baseline"]):
        with open(g["baseline"]) as f:
            base_file = json.load(f)
    # gate-tuning overrides a maintainer set on the record survive a
    # refresh (check_gate reads both from the resolved record)
    keep = ("floor", "drop_threshold")
    if base_file.get("host") in (None, key):
        hosts = base_file.get("hosts", {})
        old = base_file
        base_file = dict(latest)
        if hosts:
            base_file["hosts"] = hosts
        for k in keep:
            if k in old:
                base_file[k] = old[k]
    else:
        hosts = base_file.setdefault("hosts", {})
        old = hosts.get(key, {})
        hosts[key] = dict(latest)
        for k in keep:
            if k in old:
                hosts[key][k] = old[k]
    with open(g["baseline"], "w") as f:
        json.dump(base_file, f, indent=1, default=float)
    print(f"record[{name}]: baseline for host key {key!r} written to "
          f"{g['baseline']}")
    return EXIT_OK


def combine_codes(codes) -> int:
    """Regression dominates, then usage errors, then missing artifacts."""
    for code in (EXIT_REGRESSION, EXIT_USAGE, EXIT_MISSING):
        if code in codes:
            return code
    return EXIT_OK


def write_report(path: str, records, rc: int, threshold: float, *,
                 enforced: bool = False) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    report = {"host": host_key(), "hostname": socket.gethostname(),
              "threshold": threshold, "exit_code": rc,
              "enforced": enforced,
              "gates": {r["gate"]: r for r in records}}
    with open(path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    print(f"gate: report written to {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    ap.add_argument("--which",
                    default=",".join(GATES),
                    help="comma list of gates: " + ",".join(GATES))
    ap.add_argument("--report", default=DEFAULT_REPORT,
                    help="machine-readable per-gate report path "
                         "('' disables)")
    ap.add_argument("--enforce", action="store_true",
                    help="fail (exit 3) instead of warning when the "
                         "current host key has no recorded baseline "
                         "(also via REPRO_GATE_ENFORCE=1)")
    ap.add_argument("--record-baselines", action="store_true",
                    help="fold the latest local results into the "
                         "baseline files under the current host key "
                         "instead of gating")
    args = ap.parse_args(argv)
    names = []
    for name in args.which.split(","):
        name = name.strip()
        if name not in GATES:
            print(f"gate: unknown gate '{name}'", file=sys.stderr)
            return EXIT_USAGE
        names.append(name)
    if args.record_baselines:
        return combine_codes([record_baseline(n) for n in names])
    enforce = enforcing(args.enforce)
    codes, records = [], []
    for name in names:
        rc, rec = check_gate(name, args.threshold, enforce=enforce)
        codes.append(rc)
        records.append(rec)
    rc = combine_codes(codes)
    if args.report:
        write_report(args.report, records, rc, args.threshold,
                     enforced=enforce)
    return rc


if __name__ == "__main__":
    sys.exit(main())
