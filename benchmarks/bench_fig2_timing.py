"""Paper Fig. 2 / §II-C — completion-time comparison SFL vs AFL.

Reproduces the paper's timing analysis with the event-driven simulator and
checks the closed forms:
  homogeneous:   τ_syn = τ_d + τ + M·τ_u ;  τ_asyn sweep = M·τ_u + M·τ_d + τ
  heterogeneous: SFL waits for a·τ; AFL refreshes every τ_u + τ_d.
Emits model-update-interval statistics (the paper's key observation).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_result
from repro.core.scheduler import (AFLScheduler, homogeneous_round_times,
                                  make_fleet, sfl_round_time)


def run(M: int = 100, tau: float = 1.0, tau_u: float = 0.05,
        tau_d: float = 0.05, hetero_a: float = 10.0) -> dict:
    out = {}
    # homogeneous closed form (claim C5)
    hom = homogeneous_round_times(M, tau=tau, tau_u=tau_u, tau_d=tau_d)
    out["homogeneous"] = hom

    # heterogeneous, simulated
    fleet = make_fleet(M, tau=tau, hetero_a=hetero_a,
                       samples_per_client=[600] * M, seed=0, adaptive=False)
    sfl_t = sfl_round_time(fleet, tau_u=tau_u, tau_d=tau_d)
    evs = list(AFLScheduler(fleet, tau_u=tau_u, tau_d=tau_d).events(5 * M))
    gaps = np.diff([e.t_complete for e in evs])
    out["heterogeneous"] = {
        "sfl_round_time": sfl_t,
        "afl_update_interval_mean": float(gaps.mean()),
        "afl_update_interval_p95": float(np.percentile(gaps, 95)),
        "afl_updates_per_sfl_round": float(sfl_t / gaps.mean()),
        "staleness_mean": float(np.mean([e.staleness for e in evs])),
        "staleness_max": int(np.max([e.staleness for e in evs])),
    }
    # adaptive local iterations narrow the staleness spread (§III-C)
    fleet_a = make_fleet(M, tau=tau, hetero_a=hetero_a,
                         samples_per_client=[600] * M, seed=0, adaptive=True)
    evs_a = list(AFLScheduler(fleet_a, tau_u=tau_u, tau_d=tau_d).events(5 * M))
    out["heterogeneous_adaptive"] = {
        "staleness_mean": float(np.mean([e.staleness for e in evs_a])),
        "staleness_max": int(np.max([e.staleness for e in evs_a])),
    }
    return out


def main() -> None:
    res = run()
    save_result("fig2_timing", res)
    het = res["heterogeneous"]
    emit("fig2.sfl_round_time_s", het["sfl_round_time"] * 1e6,
         "virtual-seconds x1e-6")
    emit("fig2.afl_update_interval_s",
         het["afl_update_interval_mean"] * 1e6,
         f"updates_per_sfl_round={het['afl_updates_per_sfl_round']:.1f}")
    emit("fig2.staleness_max", het["staleness_max"],
         f"adaptive={res['heterogeneous_adaptive']['staleness_max']}")


if __name__ == "__main__":
    main()
