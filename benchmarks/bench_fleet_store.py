"""Paged fleet-store overhead benchmark (docs/DESIGN.md §12): what the
active-set row pool costs when it is NOT needed, and how fast the
host-arena -> device staging path moves rows when it is.

Two gated quantities:

* ``speedup = dense_s / paged_s`` on the paper-CNN CPU-budget compiled
  run at M=64 with a deliberately tight P=16 pool — the small-M overhead
  gate.  The paged plane pays slot bookkeeping, horizon-aware eviction
  and per-segment adopt() on exactly the workload where the dense plane
  is optimal, so this ratio sits below 1x by construction; a collapse
  (per-event host sync on the slot table, eviction write-back inside the
  hot loop, prefetch thread contention) lands far below the recorded
  floor.  Parity between the two final params is recorded and gated
  ≤1e-5 like every other plane gate.
* ``staging_ms_per_mb`` — a direct ``FleetStore`` micro-bench: swap two
  disjoint P-row working sets through the pool so every ``ensure()``
  evicts + stages P fresh rows from the host arena, and report wall ms
  per staged MB.  Checked as an extra upper bound by
  ``benchmarks/check_regression.py``; a collapse (per-row device_put,
  arena gather inside the worker lock, accidental row copies) lands far
  above.

Context (never gated): events/s for both variants, the paged run's
``peak_device_rows`` / ``prefetch_stalls`` / ``evictions`` counters —
peak stays O(P) even here, which is the whole point of the store.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, emit, save_result

M = 64
P = 16                     # deliberately tight: M/4 active slots
K = 1                      # local iterations per upload
LOCAL_BATCHES = 2          # minibatches per local iteration
BATCH_SIZE = 1
ITERATIONS = 256           # upload events per timed run
REPS = 3                   # median-of-REPS end-to-end runs per variant

# staging micro-bench geometry: 256 KiB rows, 32-row swaps (8 MiB each)
STAGE_M, STAGE_N, STAGE_P = 64, 65536, 32
STAGE_SWAPS = 16


def bench_store_runs() -> None:
    import jax

    from repro import api
    from repro.configs.paper_cnn import CNNConfig
    from repro.core.scheduler import make_fleet
    from repro.core.tasks import CNNTask

    seed = bench_seed()
    cnn_cfg = CNNConfig(conv1=2, conv2=4, fc=16)   # CPU-budget width
    task = CNNTask(iid=True, num_clients=M, train_n=2048, test_n=128,
                   batch_size=BATCH_SIZE,
                   local_batches_per_step=LOCAL_BATCHES,
                   cnn_cfg=cnn_cfg, seed=seed)
    fleet = make_fleet(M, tau=1.0, hetero_a=4.0,
                       samples_per_client=task.num_samples(),
                       adaptive=False, base_local_steps=K, seed=seed)
    p0 = task.init_params()
    dense = task.client_plane(fleet)
    paged = task.client_plane(fleet, store="paged", active_slots=P)
    cfg = api.RunConfig(algorithm="csmaafl", loop="compiled",
                        iterations=ITERATIONS, gamma=0.4,
                        eval_every=ITERATIONS, seed=seed,
                        timing=api.TimingConfig(tau_u=0.1, tau_d=0.1))

    def one(plane):
        return api.run(task, cfg, fleet=fleet, client_plane=plane,
                       params0=p0)

    def timed(plane):
        r = one(plane)                 # warmup compiles the variant
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        ts = []
        for _ in range(REPS):
            t0 = time.perf_counter()
            r = one(plane)
            jax.block_until_ready(jax.tree.leaves(r.params)[0])
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts)), r

    t_dense, r_dense = timed(dense)
    t_paged, r_paged = timed(paged)

    speedup = t_dense / t_paged
    parity = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                     - np.asarray(b, np.float32))))
                 for a, b in zip(jax.tree.leaves(r_paged.params),
                                 jax.tree.leaves(r_dense.params)))
    counters = {k: r_paged.stats[k] for k in
                ("peak_device_rows", "prefetch_stalls", "evictions")}
    staging = bench_staging()
    emit("fleet_store.compiled.dense", t_dense * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_dense:.1f} events/s "
         f"(dense, {r_dense.stats['peak_device_rows']} device rows)")
    emit("fleet_store.compiled.paged", t_paged * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_paged:.1f} events/s; {1 / speedup:.3f}x dense "
         f"at P={P}; parity {parity:.2e}; "
         f"peak_rows={counters['peak_device_rows']} "
         f"stalls={counters['prefetch_stalls']}")
    emit("fleet_store.staging", staging["staging_us_per_swap"],
         f"{staging['staging_ms_per_mb']:.3f} ms/MB arena->device "
         f"({STAGE_P} rows x {STAGE_N} f32 per swap)")
    save_result("fleet_store", {
        "model": "paper_cnn_cpu_budget", "M": M, "P": P, "K": K,
        "local_batches": LOCAL_BATCHES, "batch_size": BATCH_SIZE,
        "iterations": ITERATIONS, "seed": seed,
        "mode": dense.engine.mode,
        "stage_rows": STAGE_P, "stage_row_floats": STAGE_N,
        "dense_s": t_dense, "paged_s": t_paged,
        "events_per_s_dense": ITERATIONS / t_dense,
        "events_per_s_paged": ITERATIONS / t_paged,
        "paged_peak_device_rows": counters["peak_device_rows"],
        "paged_prefetch_stalls": counters["prefetch_stalls"],
        "paged_evictions": counters["evictions"],
        "speedup": speedup,
        "parity_max_abs_diff": parity,
        "staging_ms_per_mb": staging["staging_ms_per_mb"],
    })


def bench_staging() -> dict:
    """Time pure arena->device staging: alternate two disjoint P-row
    working sets so every ``ensure()`` evicts one full set and stages the
    other from the host arena."""
    import jax
    import jax.numpy as jnp

    from repro.core.fleet_store import FleetStore

    rng = np.random.default_rng(bench_seed())
    store = FleetStore(STAGE_M, STAGE_N, STAGE_P, np.float32)
    store.write_rows(np.arange(STAGE_M),
                     rng.standard_normal((STAGE_M, STAGE_N), np.float32))
    pool = jnp.zeros((STAGE_P, STAGE_N), jnp.float32)
    sets = [np.arange(0, STAGE_P), np.arange(STAGE_P, 2 * STAGE_P)]
    pool = store.ensure(pool, sets[0])      # warmup: compile + first fill
    jax.block_until_ready(pool)
    t0 = time.perf_counter()
    for i in range(STAGE_SWAPS):
        pool = store.ensure(pool, sets[(i + 1) % 2])
    jax.block_until_ready(pool)
    dt = time.perf_counter() - t0
    mb = STAGE_SWAPS * STAGE_P * STAGE_N * 4 / 2**20
    return {"staging_ms_per_mb": dt * 1e3 / mb,
            "staging_us_per_swap": dt * 1e6 / STAGE_SWAPS}


def main() -> None:
    bench_store_runs()


if __name__ == "__main__":
    main()
