"""Shared benchmark utilities: timing, CSV emission, result persistence.

Results write to the GITIGNORED ``experiments/bench/local/`` by default —
running a bench locally must not dirty the tree (PRs 1-4 kept rewriting
the committed host-recorded results on every run).  Pass
``benchmarks.run --record`` (or set ``REPRO_BENCH_RECORD=1``) to ALSO
refresh the tracked ``experiments/bench/*.json`` record.
"""
from __future__ import annotations

import json
import os
import socket
import time
from typing import Any, Callable, Dict

import jax
import numpy as np

RECORD_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "bench")
RESULTS_DIR = os.path.join(RECORD_DIR, "local")


def bench_seed(default: int = 0) -> int:
    """The run-to-run-deterministic bench seed.  ``benchmarks.run --seed``
    exports it as ``REPRO_BENCH_SEED`` so every bench (including ones that
    re-exec themselves in a subprocess) draws the same fleets/batches."""
    return int(os.environ.get("REPRO_BENCH_SEED", default))


def recording() -> bool:
    return os.environ.get("REPRO_BENCH_RECORD", "") not in ("", "0")


def time_fn(fn: Callable[[], Any], *, warmup: int = 2, iters: int = 10
            ) -> float:
    """Median wall time per call in microseconds (block_until_ready-aware)."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def save_result(name: str, payload: Dict[str, Any]) -> str:
    # baselines/floors are keyed by host key (check_regression.py): an
    # unknown CI host then warns instead of false-failing the gates
    from benchmarks.check_regression import host_key
    payload.setdefault("host", host_key())
    payload.setdefault("hostname", socket.gethostname())
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    if recording():
        with open(os.path.join(RECORD_DIR, f"{name}.json"), "w") as f:
            json.dump(payload, f, indent=1, default=float)
    return path
