"""Sweep-plane benchmark: aggregate events/s for a seeds x scenarios
grid through the run-batched sweep plane (docs/DESIGN.md §8) vs the same
grid as sequential ``compiled_loop=True`` runs.

Two grids, one regime argument (the PR-2/PR-4 convention):

* ``grid_toy`` (GATED speedup) — a flat-vector task whose per-op device
  cost is tiny, i.e. the dispatch-light end of the spectrum where the
  loop STRUCTURE is what's being measured.  Sequential pays R full
  pipelines (scheduler simulation, staging, per-run launches);
  the sweep shares one scheduler simulation per scenario
  (``Scenario.fleet_seed`` pins the device population, so seeds vary
  data/init only), bulk-stacks the staged events straight into the
  (L, R, ...) layout, and executes the whole grid as a handful of
  run-batched donated scans.  This is the "someone re-introduced
  per-run host looping / per-run launches" regression signal.
* ``grid_cnn`` (context + GATED parity) — the paper-grid configuration
  (CPU-budget paper CNN at M=64).  On this 2-core container XLA:CPU's
  conv kernels cost ~500us per *sample* and scale linearly with batch,
  so every configuration is conv-compute-bound and run-batching is
  worth ~1x end-to-end — the honest number is recorded as context, and
  the per-run final-params parity vs the sequential runs is gated
  ≤ 1e-5.  On accelerator hosts (conv ~us, dispatch ~10-100us/launch)
  the same grid sits in the toy's regime; re-record there.

Both timed passes include trace compilation and host-side staging (the
sweep restages and restacks every pass, exactly like the sequential
runs); planes and compiled programs are warm in both (one warmup pass
each), and the timed value is the median of 3 passes.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_seed, emit, save_result

M = 64
ITER_TOY = 256
ITER_CNN = 64
EVAL_EVERY = 16
SEEDS_TOY = 8
SEEDS_CNN = 4
SCENARIO_NAMES = ("paper_iid", "paper_noniid", "uplink_bound")
TOY_D = 1024
K = 1                      # local iterations per upload
LOCAL_BATCHES = 2          # minibatches per local iteration


def _scenarios(fleet_seed):
    from repro.core import sweep_plane as sp
    return [sp.resolve_scenario({"name": n, "fleet_seed": fleet_seed})
            for n in SCENARIO_NAMES]


def _timed(run_fn, leaf_fn, passes=3):
    import jax
    jax.block_until_ready(leaf_fn(run_fn()))      # warmup compiles
    ts = []
    for _ in range(passes):
        t0 = time.perf_counter()
        out = run_fn()
        jax.block_until_ready(leaf_fn(out))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def _grid_toy(seed0: int):
    """Flat-vector grid: staging is pool slicing, the model is a
    target-pull update — ~everything left is loop structure.  Eval
    curves are ON (a convergence grid without histories is not the
    paper's workload): the sequential loop pays R host-synced eval
    fetches per eval point, the sweep one vmapped launch."""
    import jax.numpy as jnp

    from repro.core import event_trace as et
    from repro.core import sweep_plane as sp
    from repro.core.afl import run_afl
    from repro.core.agg_engine import AggEngine
    from repro.core.client_plane import ClientPlane

    rng = np.random.default_rng(seed0)
    w0 = jnp.asarray(rng.normal(size=TOY_D), jnp.float32)
    pool = rng.normal(size=(257, TOY_D)).astype(np.float32)

    def batch_fn(cid, num_steps, seed_):
        i = (seed_ * 131 + cid) % (257 - num_steps)
        return pool[i:i + num_steps]

    def step(flat, target):
        return flat - 0.25 * (flat - target)

    def eval_fn(params):
        return {"s": float(jnp.sum(jnp.asarray(params, jnp.float32)))}

    def eval_flat(g_flat):
        return {"s": jnp.sum(g_flat.astype(jnp.float32))}

    scens = _scenarios(seed0 + 7)
    seeds = [seed0 + s for s in range(SEEDS_TOY)]
    planes = {}
    for sc in scens:
        for seed in seeds:
            fleet = sc.make_fleet([60 + 10 * (m % 7) for m in range(M)],
                                  seed)
            planes[(sc.name, seed)] = ClientPlane(
                AggEngine(w0), fleet, step, batch_fn)
    g0 = planes[(scens[0].name, seeds[0])].engine.flatten(w0)

    def build_runs():
        runs = []
        for sc in scens:
            ev = None
            for seed in seeds:
                p = planes[(sc.name, seed)]
                trace = et.compile_afl_trace(
                    p.fleet, algorithm=sc.algorithm, iterations=ITER_TOY,
                    tau_u=sc.tau_u, tau_d=sc.tau_d, gamma=sc.gamma,
                    seed=seed, events=ev)
                ev = trace.events
                runs.append(sp.SweepRun(sc, seed, p, trace, g0,
                                        label=f"{sc.name}/s{seed}"))
        return runs

    def run_sequential():
        outs = []
        for sc in scens:
            for seed in seeds:
                p = planes[(sc.name, seed)]
                outs.append(run_afl(
                    w0, p.fleet, None, algorithm=sc.algorithm,
                    iterations=ITER_TOY, tau_u=sc.tau_u, tau_d=sc.tau_d,
                    gamma=sc.gamma, eval_fn=eval_fn,
                    eval_every=EVAL_EVERY, client_plane=p,
                    compiled_loop=True, seed=seed))
        return outs

    def run_sweep():
        return sp.SweepRunner(build_runs(), eval_flat=eval_flat,
                              eval_every=EVAL_EVERY).run()

    R = len(scens) * len(seeds)
    t_seq, solos = _timed(run_sequential, lambda o: o[-1].params)
    t_swp, sweep = _timed(run_sweep, lambda o: o.params[-1])
    parity = max(float(np.max(np.abs(
        np.asarray(a, np.float32) - np.asarray(s.params, np.float32))))
        for a, s in zip(sweep.params, solos))
    return {"runs": R, "events": R * ITER_TOY, "seq_s": t_seq,
            "sweep_s": t_swp, "speedup": t_seq / t_swp,
            "parity": parity, "launches": sweep.stats["launches"],
            "groups": sweep.stats["groups"]}


def _grid_cnn(seed0: int):
    """The paper-grid configuration (context + parity)."""
    import jax

    from repro.configs.paper_cnn import CNNConfig
    from repro.core import event_trace as et
    from repro.core import sweep_plane as sp
    from repro.core.afl import run_afl
    from repro.core.tasks import CNNTask

    cnn_cfg = CNNConfig(conv1=2, conv2=4, fc=16)   # CPU-budget width
    task = CNNTask(iid=True, num_clients=M, train_n=4096, test_n=128,
                   batch_size=1, local_batches_per_step=LOCAL_BATCHES,
                   cnn_cfg=cnn_cfg, seed=seed0)
    scens = _scenarios(seed0 + 7)
    seeds = [seed0 + s for s in range(SEEDS_CNN)]
    base_runs = sp.build_task_runs(task, scens, seeds,
                                   iterations=ITER_CNN)

    def build_runs():
        runs = []
        i = 0
        for sc in scens:
            ev = None
            for seed in seeds:
                base = base_runs[i]
                i += 1
                trace = et.compile_afl_trace(
                    base.plane.fleet, algorithm=sc.algorithm,
                    iterations=ITER_CNN, tau_u=sc.tau_u, tau_d=sc.tau_d,
                    gamma=sc.gamma, seed=seed, events=ev)
                ev = trace.events
                runs.append(sp.SweepRun(sc, seed, base.plane, trace,
                                        base.g0_flat, label=base.label))
        return runs

    def run_sequential():
        outs = []
        for r in base_runs:
            sc = r.scenario
            outs.append(run_afl(
                task.init_params(r.seed), r.plane.fleet, None,
                algorithm=sc.algorithm, iterations=ITER_CNN,
                tau_u=sc.tau_u, tau_d=sc.tau_d, gamma=sc.gamma,
                client_plane=r.plane, compiled_loop=True, seed=r.seed))
        return outs

    def run_sweep():
        return sp.SweepRunner(build_runs()).run()

    R = len(base_runs)
    t_seq, solos = _timed(run_sequential, lambda o: o[-1].params["fc2_w"])
    t_swp, sweep = _timed(run_sweep, lambda o: o.params[-1]["fc2_w"])
    parity = max(
        max(float(np.max(np.abs(np.asarray(a, np.float32)
                                - np.asarray(b, np.float32))))
            for a, b in zip(jax.tree.leaves(sp_params),
                            jax.tree.leaves(solo.params)))
        for sp_params, solo in zip(sweep.params, solos))
    return {"runs": R, "events": R * ITER_CNN, "seq_s": t_seq,
            "sweep_s": t_swp, "speedup": t_seq / t_swp,
            "parity": parity, "launches": sweep.stats["launches"],
            "groups": sweep.stats["groups"]}


def bench_sweep_plane() -> None:
    seed0 = bench_seed()
    toy = _grid_toy(seed0)
    cnn = _grid_cnn(seed0)
    emit("sweep_plane.toy.sequential", toy["seq_s"] * 1e6 / toy["events"],
         f"{toy['events'] / toy['seq_s']:.0f} events/s "
         f"({toy['runs']} solo compiled runs)")
    emit("sweep_plane.toy.batched", toy["sweep_s"] * 1e6 / toy["events"],
         f"{toy['events'] / toy['sweep_s']:.0f} events/s; "
         f"{toy['speedup']:.2f}x vs sequential; {toy['launches']} "
         f"launches / {toy['groups']} group(s)")
    emit("sweep_plane.cnn.sequential", cnn["seq_s"] * 1e6 / cnn["events"],
         f"{cnn['events'] / cnn['seq_s']:.0f} events/s "
         f"({cnn['runs']} solo compiled runs)")
    emit("sweep_plane.cnn.batched", cnn["sweep_s"] * 1e6 / cnn["events"],
         f"{cnn['events'] / cnn['sweep_s']:.0f} events/s; "
         f"{cnn['speedup']:.2f}x (conv-bound host — context); "
         f"parity {cnn['parity']:.2e}")
    save_result("sweep_plane", {
        "model": "flat_toy+paper_cnn_cpu_budget", "M": M,
        "toy_d": TOY_D, "K": K, "local_batches": LOCAL_BATCHES,
        "iterations_toy": ITER_TOY, "iterations_cnn": ITER_CNN,
        "runs_toy": toy["runs"], "runs_cnn": cnn["runs"],
        "scenarios": list(SCENARIO_NAMES), "seed": seed0,
        "sequential_s_toy": toy["seq_s"], "sweep_s_toy": toy["sweep_s"],
        "events_per_s_sequential_toy": toy["events"] / toy["seq_s"],
        "events_per_s_sweep_toy": toy["events"] / toy["sweep_s"],
        "sequential_s_cnn": cnn["seq_s"], "sweep_s_cnn": cnn["sweep_s"],
        "events_per_s_sequential_cnn": cnn["events"] / cnn["seq_s"],
        "events_per_s_sweep_cnn": cnn["events"] / cnn["sweep_s"],
        "speedup_cnn": cnn["speedup"],
        "sweep_launches_toy": toy["launches"],
        "sweep_launches_cnn": cnn["launches"],
        "sweep_groups_toy": toy["groups"],
        "sweep_groups_cnn": cnn["groups"],
        # the GATED pair: loop-structure speedup on the dispatch-light
        # grid; numerical parity on the paper grid
        "speedup": toy["speedup"],
        "parity_max_abs_diff": max(cnn["parity"], toy["parity"]),
    })


def main() -> None:
    bench_sweep_plane()


if __name__ == "__main__":
    main()
