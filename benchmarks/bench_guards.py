"""Recovery-plane overhead benchmark (docs/DESIGN.md §10): what the
in-scan update guards and the crash-safe autosaves cost a compiled run.

Both features ride the hot loop — the guard adds one float32 norm +
``where``-mask cascade per scan step, the autosave adds a durable
(tmp+fsync+rename, SHA-256) state write every ``AUTOSAVE`` events at
segment boundaries — so both are gated as SAME-RUN ratios against the
plain compiled run on the paper-CNN CPU-budget workload
(``bench_compiled_loop``'s geometry):

* ``speedup = plain_s / guarded_s`` must stay ≥ 1/1.15 (the ISSUE's
  "guarded ≤ 1.15x unguarded" bound; floor 0.87).  A collapse (guard
  state falling off the scan carry into per-event host hops, a
  per-event device→host sync on the verdict) lands far below.
* ``autosave_overhead = autosave_s / plain_s − 1`` must stay ≤ 5% at
  ``--autosave 64`` (checked as an extra bound by
  ``benchmarks/check_regression.py``).  A collapse (checkpointing every
  event, serializing inside the scan, fsync per leaf) lands far above.

Also records guards-on/guards-off parity on the final params — over
clean data the guard is a BITWISE no-op (``row_eff`` is the original
row object when clipping is off), so the recorded parity is 0.0, gated
≤1e-5 — and the guard counters (all zero on clean data) as context.
"""
from __future__ import annotations

import os
import shutil
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, bench_seed, emit, save_result

M = 64
K = 1                      # local iterations per upload
LOCAL_BATCHES = 2          # minibatches per local iteration
BATCH_SIZE = 1
ITERATIONS = 256           # upload events per timed run
AUTOSAVE = 64              # events between durable autosaves
REPS = 3                   # median-of-REPS end-to-end runs per variant


def bench_guards() -> None:
    import jax

    from repro.configs.paper_cnn import CNNConfig
    from repro.core.afl import run_afl
    from repro.core.scheduler import make_fleet
    from repro.core.tasks import CNNTask

    seed = bench_seed()
    cnn_cfg = CNNConfig(conv1=2, conv2=4, fc=16)   # CPU-budget width
    task = CNNTask(iid=True, num_clients=M, train_n=2048, test_n=128,
                   batch_size=BATCH_SIZE,
                   local_batches_per_step=LOCAL_BATCHES,
                   cnn_cfg=cnn_cfg, seed=seed)
    fleet = make_fleet(M, tau=1.0, hetero_a=4.0,
                       samples_per_client=task.num_samples(),
                       adaptive=False, base_local_steps=K, seed=seed)
    p0 = task.init_params()
    plane = task.client_plane(fleet)
    ckdir = os.path.join(RESULTS_DIR, "bench_guards_ck")

    def one(**kw):
        return run_afl(p0, fleet, None, algorithm="csmaafl",
                       iterations=ITERATIONS, tau_u=0.1, tau_d=0.1,
                       gamma=0.4, client_plane=plane, compiled_loop=True,
                       seed=seed, **kw)

    def timed(**kw):
        r = one(**kw)                  # warmup compiles the variant
        jax.block_until_ready(jax.tree.leaves(r.params)[0])
        ts = []
        for _ in range(REPS):
            if "autosave_dir" in kw:   # each rep writes a fresh family
                shutil.rmtree(ckdir, ignore_errors=True)
            t0 = time.perf_counter()
            r = one(**kw)
            jax.block_until_ready(jax.tree.leaves(r.params)[0])
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts)), r

    try:
        t_plain, r_plain = timed()
        t_grd, r_grd = timed(guards="default")
        t_save, _ = timed(autosave_every=AUTOSAVE, autosave_dir=ckdir)
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)

    speedup = t_plain / t_grd
    overhead = t_save / t_plain - 1.0
    parity = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                     - np.asarray(b, np.float32))))
                 for a, b in zip(jax.tree.leaves(r_grd.params),
                                 jax.tree.leaves(r_plain.params)))
    counters = {k: v for k, v in r_grd.stats["faults"].items()
                if k.startswith("guard_")}
    emit("guards.run_afl.plain", t_plain * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_plain:.1f} events/s (compiled, unguarded)")
    emit("guards.run_afl.guarded", t_grd * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_grd:.1f} events/s; {1 / speedup:.3f}x plain "
         f"(bound 1.15x); parity {parity:.2e}; "
         f"rejects={counters.get('guard_rejects', 0)}")
    emit("guards.run_afl.autosave", t_save * 1e6 / ITERATIONS,
         f"{ITERATIONS / t_save:.1f} events/s; {overhead * 100:+.1f}% "
         f"overhead at --autosave {AUTOSAVE} (bound +5%)")
    save_result("guards", {
        "model": "paper_cnn_cpu_budget", "M": M, "K": K,
        "local_batches": LOCAL_BATCHES, "batch_size": BATCH_SIZE,
        "iterations": ITERATIONS, "autosave_every": AUTOSAVE,
        "seed": seed, "mode": plane.engine.mode,
        "plain_s": t_plain, "guarded_s": t_grd, "autosave_s": t_save,
        "events_per_s_plain": ITERATIONS / t_plain,
        "events_per_s_guarded": ITERATIONS / t_grd,
        "events_per_s_autosave": ITERATIONS / t_save,
        "guard_counters": counters,
        "speedup": speedup, "autosave_overhead": overhead,
        "parity_max_abs_diff": parity,
    })


def main() -> None:
    bench_guards()


if __name__ == "__main__":
    main()
