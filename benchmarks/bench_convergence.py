"""Paper Figs. 3-5 — convergence benchmarks: FedAvg (SFL) vs CSMAAFL with a
γ sweep, on the MNIST-like and Fashion-like procedural datasets, IID and
non-IID, accuracy vs *relative time slots* (the paper's x-axis).

Full-paper scale is 100 clients × 60k images; the default here is a scaled
configuration (CPU-budget) that preserves every qualitative claim; pass
``--full`` for paper scale.

Claims validated (recorded into experiments/paper_repro):
  C3: CSMAAFL reaches FedAvg-level accuracy but leads at equal virtual time
      early in training.
  C4: γ=0.1 degenerates (over-emphasized client contribution);
      mid-range γ works best.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import numpy as np

from benchmarks.common import emit, save_result
from repro.configs.paper_cnn import FASHION_CNN, MNIST_CNN
from repro.core.afl import run_afl
from repro.core.scheduler import make_fleet
from repro.core.sfl import run_fedavg
from repro.core.tasks import CNNTask


def run_scenario(variant: str, iid: bool, *, num_clients: int,
                 train_n: int, rounds: int, gammas: List[float],
                 tau_u: float = 0.05, tau_d: float = 0.05,
                 seed: int = 0) -> Dict:
    cnn_cfg = MNIST_CNN if variant == "digits" else FASHION_CNN
    task = CNNTask(variant=variant, iid=iid, num_clients=num_clients,
                   train_n=train_n, test_n=2000, cnn_cfg=cnn_cfg,
                   local_batches_per_step=4, seed=seed)
    fleet = make_fleet(num_clients, tau=1.0, hetero_a=8.0,
                       samples_per_client=task.num_samples(), seed=seed)
    p0 = task.init_params(seed)
    out = {"variant": variant, "iid": iid, "curves": {}}

    # SFL / FedAvg reference
    _, hist = run_fedavg(p0, fleet, task.local_train_fn, rounds=rounds,
                         tau_u=tau_u, tau_d=tau_d, eval_fn=task.eval_fn,
                         local_steps_override=1)
    out["curves"]["fedavg"] = {"t": hist.times,
                               "acc": [m["accuracy"] for m in hist.metrics]}
    sfl_end_time = hist.times[-1]

    # CSMAAFL at matched virtual time for each gamma
    for gamma in gammas:
        # iterate until the same virtual-time horizon as SFL
        probe = run_afl(p0, fleet, task.local_train_fn,
                        algorithm="csmaafl", iterations=num_clients,
                        tau_u=tau_u, tau_d=tau_d, gamma=gamma, seed=seed)
        per_iter = probe.events[-1].t_complete / num_clients
        iters = max(int(sfl_end_time / per_iter), num_clients)
        res = run_afl(p0, fleet, task.local_train_fn, algorithm="csmaafl",
                      iterations=iters, tau_u=tau_u, tau_d=tau_d,
                      gamma=gamma, eval_fn=task.eval_fn,
                      eval_every=max(iters // (2 * len(out["curves"]) + 10),
                                     num_clients // 2),
                      seed=seed)
        out["curves"][f"csmaafl_g{gamma}"] = {
            "t": res.history.times,
            "acc": [m["accuracy"] for m in res.history.metrics]}
    return out


def early_lead(curves: Dict, t_frac: float = 0.35) -> Dict[str, float]:
    """Accuracy of each curve at t_frac of the FedAvg horizon."""
    t_end = curves["fedavg"]["t"][-1]
    t_probe = t_frac * t_end
    res = {}
    for name, c in curves.items():
        t, acc = np.asarray(c["t"]), np.asarray(c["acc"])
        res[name] = float(np.interp(t_probe, t, acc))
    return res


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper scale: 100 clients, 60k images")
    ap.add_argument("--scenarios", default="mnist_iid,mnist_noniid",
                    help="comma list from mnist_iid,mnist_noniid,"
                         "fashion_iid,fashion_noniid")
    args = ap.parse_args(argv)
    if args.full:
        kw = dict(num_clients=100, train_n=60000, rounds=12,
                  gammas=[0.1, 0.2, 0.4, 0.6])
    else:
        kw = dict(num_clients=16, train_n=6400, rounds=8,
                  gammas=[0.1, 0.4])
    for scen in args.scenarios.split(","):
        variant = "digits" if scen.startswith("mnist") else "fashion"
        iid = scen.endswith("_iid")
        res = run_scenario(variant, iid, **kw)
        res["early_lead@0.35T"] = early_lead(res["curves"])
        res["final"] = {k: c["acc"][-1] for k, c in res["curves"].items()}
        save_result(f"convergence_{scen}", res)
        lead = res["early_lead@0.35T"]
        best_g = max((k for k in lead if k.startswith("csmaafl")),
                     key=lambda k: lead[k])
        emit(f"fig345.{scen}.final_fedavg",
             res["final"]["fedavg"] * 1e6, "accuracy x1e-6")
        emit(f"fig345.{scen}.final_{best_g}",
             res["final"][best_g] * 1e6, "accuracy x1e-6")
        emit(f"fig345.{scen}.early_lead",
             (lead[best_g] - lead["fedavg"]) * 1e6,
             f"acc-delta@0.35T x1e-6 ({best_g})")


if __name__ == "__main__":
    main()
